"""Assigned input shapes and per-(arch × shape) input specs for the dry-run.

Shapes (assignment):
  train_4k     seq=4096    global_batch=256   → train_step
  prefill_32k  seq=32768   global_batch=32    → prefill_step
  decode_32k   seq=32768   global_batch=128   → serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq=524288  global_batch=1     → serve_step; requires
               sub-quadratic attention: runs only for swa/hybrid/ssm archs
               (cfg.supports_long_context), skipped for full attention.

``input_specs`` returns ShapeDtypeStructs only — no allocation; the dry-run
lowers against them (the shannon/kernels pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# fraction of the sequence that is stub frontend embeddings
VIS_FRACTION = 8            # qwen2-vl: S/8 positions are patch embeddings
ENC_FRACTION = 4            # seamless: encoder frames = S/4 (audio stride)


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: long_500k skipped (DESIGN.md)"
    return True, ""


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f(cfg, *shape):
    return jax.ShapeDtypeStruct(shape, cfg.param_dtype)


def cache_max_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Decode-cache length: seq_len, or the SWA window in long-context
    serving mode (ring buffer — the sub-quadratic memory story)."""
    if shape.name == "long_500k" and cfg.attn_kind == "swa" and cfg.window:
        return cfg.window
    return shape.seq_len


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    b = shape.global_batch
    enc_len = shape.seq_len // ENC_FRACTION if cfg.is_encdec else 0
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, cache_max_len(cfg, shape),
                                       enc_len=enc_len))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch: Dict[str, Any] = {"tokens": _i32(b, s), "targets": _i32(b, s)}
        if cfg.family == "vlm":
            batch["pixel_embeds"] = _f(cfg, b, s // VIS_FRACTION, cfg.d_model)
            batch["positions3"] = _i32(3, b, s)
        if cfg.is_encdec:
            batch["enc_frames"] = _f(cfg, b, s // ENC_FRACTION, cfg.d_model)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _i32(b, s)}
        if cfg.family == "vlm":
            batch["pixel_embeds"] = _f(cfg, b, s // VIS_FRACTION, cfg.d_model)
            batch["positions3"] = _i32(3, b, s)
        if cfg.is_encdec:
            batch["enc_frames"] = _f(cfg, b, s // ENC_FRACTION, cfg.d_model)
        return {"batch": batch, "cache": abstract_cache(cfg, shape)}
    # decode: one new token against a cache of seq_len
    specs: Dict[str, Any] = {"token": _i32(b, 1),
                             "cache": abstract_cache(cfg, shape)}
    if cfg.family == "vlm":
        specs["positions3"] = _i32(3, b, 1)
    return specs
