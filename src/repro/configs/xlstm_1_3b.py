"""xlstm-1.3b — sLSTM + mLSTM block stack.  [arXiv:2405.04517; unverified]

48L d_model=2048 4H vocab=50304, d_ff=0 (no separate FFN; the mLSTM
up/down projections carry the capacity).  Layers are organised as 6 groups
of (7 mLSTM + 1 sLSTM) — the paper's ~7:1 ratio — so both stacks scan with
uniform parameters.  Recurrent state instead of KV cache ⇒ O(1)/token
decode: long_500k RUNS.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab_size=50304,
    ssm_expand=2, slstm_every=8, ssm_conv=4,
    rope_style="none", supports_long_context=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=0, vocab_size=256,
    ssm_expand=2, slstm_every=2, ssm_conv=4,
    rope_style="none", supports_long_context=True, tie_embeddings=True,
    dtype="float32",
)
