"""granite-moe-3b-a800m — 40 experts top-8, small expert FFNs.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
(Expert count is padded to the model-parallel degree at parameter-build
time: 40 → 48 on a 16-way TP mesh, padding experts masked in the router.)
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8, capacity_factor=1.25,
    act="silu", rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256,
    n_experts=5, top_k=2, capacity_factor=1.25,
    act="silu", dtype="float32",
)
