"""hymba-1.5b — hybrid: parallel attention + Mamba heads per layer.

[arXiv:2411.13676; hf]
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
SWA (window 1024) on all layers except global layers {0, 15, 31}; the
published model's 128 meta-tokens are omitted (DESIGN.md §7).
Hybrid SWA+SSM ⇒ sub-quadratic: long_500k RUNS (global layers drop to the
sliding window in the long-context serving mode — see DESIGN.md).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    attn_kind="swa", window=1024, global_layers=(0, 15, 31),
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    act="silu", rope_theta=10000.0, supports_long_context=True,
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    attn_kind="swa", window=8, global_layers=(1,),
    ssm_state=4, ssm_conv=4, ssm_expand=2,
    act="silu", supports_long_context=True, dtype="float32",
)
