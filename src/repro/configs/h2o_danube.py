"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf]
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
SWA ⇒ sub-quadratic decode with a ring-buffer cache: long_500k RUNS.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000,
    act="silu", rope_theta=10000.0,
    attn_kind="swa", window=4096, supports_long_context=True,
)

SMOKE = ModelConfig(
    name="danube-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, act="silu",
    attn_kind="swa", window=8, supports_long_context=True, dtype="float32",
)
