"""qwen2-vl-72b — VLM backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191; hf]
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
The vision tower is a STUB per the assignment: ``input_specs`` provides
pre-projected patch embeddings (B, S_vis, d_model) that occupy the leading
positions; M-RoPE (sections 16/24/24 of head_dim/2) consumes the 3-stream
(t, h, w) position ids.  Full attention ⇒ long_500k skipped.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    rope_style="mrope", mrope_sections=(16, 24, 24),
    act="silu", rope_theta=1000000.0, qk_norm=False,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    rope_style="mrope", mrope_sections=(4, 2, 2),
    act="silu", dtype="float32",
)
