"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf]
24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
The audio frontend (w2v-BERT conformer feature extractor) is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings
(B, S_enc, d_model); the transformer encoder/decoder backbone is fully
implemented (cross-attention, cached at prefill).
Full attention enc-dec ⇒ long_500k skipped.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206,
    act="silu", rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=96, vocab_size=256, act="silu", dtype="float32",
)
