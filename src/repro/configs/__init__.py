"""Architecture registry: ``get(arch_id)`` → (FULL, SMOKE) ModelConfigs."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.models.config import ModelConfig
from repro.configs import (gemma7b, granite_moe, h2o_danube, hymba,
                           llama4_scout, minicpm3, qwen2_vl, seamless_m4t,
                           xlstm_1_3b, yi6b)

_MODULES = {
    "llama4-scout-17b-a16e": llama4_scout,
    "granite-moe-3b-a800m": granite_moe,
    "yi-6b": yi6b,
    "gemma-7b": gemma7b,
    "h2o-danube-1.8b": h2o_danube,
    "minicpm3-4b": minicpm3,
    "seamless-m4t-large-v2": seamless_m4t,
    "hymba-1.5b": hymba,
    "qwen2-vl-72b": qwen2_vl,
    "xlstm-1.3b": xlstm_1_3b,
}

ARCH_IDS = tuple(_MODULES)


def get(arch: str) -> ModelConfig:
    return _MODULES[arch].FULL


def get_smoke(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def all_full() -> Dict[str, ModelConfig]:
    return {k: m.FULL for k, m in _MODULES.items()}
