"""minicpm3-4b — Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf]
62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA dims follow the released config: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64.  The decode cache stores only
(kv_lora + qk_rope) = 288 values/token — 11× smaller than GQA-40.
MLA is still full attention ⇒ long_500k skipped.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm3-4b", family="mla",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=96,
    d_ff=6400, vocab_size=73448,
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    act="silu", rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="minicpm3-smoke", family="mla",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
    d_ff=96, vocab_size=256,
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, act="silu", dtype="float32",
)
