"""gemma-7b — GeGLU, head_dim=256, MHA (kv=16), sqrt(d) embedding scale.

[arXiv:2403.08295; hf]
28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.
Full attention ⇒ long_500k skipped.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    act="gelu", rope_theta=10000.0, embed_scale=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=256, act="gelu", embed_scale=True,
    tie_embeddings=True, dtype="float32",
)
