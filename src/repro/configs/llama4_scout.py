"""llama4-scout-17b-a16e — MoE, 16 experts top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.

Note: Llama-4 interleaves dense/MoE FFNs in the released model; the
assignment specifies the MoE figures only, so every layer is MoE here
(uniform stacks → single lax.scan; recorded in DESIGN.md).  Long context in
the real model uses iRoPE/chunked attention; this backbone is full-attention
⇒ long_500k is skipped (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    n_experts=16, top_k=1, capacity_factor=1.25,
    act="silu", rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    n_experts=4, top_k=1, capacity_factor=1.25,
    act="silu", dtype="float32",
)
