"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, sequential) — for the xlstm-1.3b architecture.

mLSTM recurrence per head (states C: (dk, dv), n: (dk,), m: scalar):
    m_t = max(f̃_t + m_{t-1}, ĩ_t)
    f'  = exp(f̃_t + m_{t-1} − m_t),  i' = exp(ĩ_t − m_t)
    C_t = f' C_{t-1} + i' k_t v_tᵀ,   n_t = f' n_{t-1} + i' k_t
    y_t = (C_tᵀ q_t) / max(|n_tᵀ q_t|, exp(−m_t))

Training runs the *chunkwise* form: an outer lax.scan over chunks carries
(C, n, m); within a chunk the contributions are computed in parallel with an
(L×L) masked gate matrix in log space (exact, stabilized by the running max
— the same trick the official CUDA kernels implement).  Decode is the O(1)
per-token step.  All state math in f32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

MLSTM_CHUNK = 64
_NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_chunk(carry, qkvif):
    """One chunk.  carry: C (B,H,dk,dv), n (B,H,dk), m (B,H).

    q,k: (B,L,H,dk); v: (B,L,H,dv); i_g,f_g: (B,L,H) raw gate pre-acts.
    Exact chunkwise-parallel evaluation of the recurrence above.
    """
    C0, n0, m0 = carry
    q, k, v, i_g, f_g = qkvif
    orig_dtype = v.dtype
    B, L, H, dk = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dk, jnp.float32))
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32) * scale
    v = v.astype(jnp.float32)
    i_g = i_g.astype(jnp.float32)
    f_g = jax.nn.log_sigmoid(f_g.astype(jnp.float32))   # log f ∈ (−inf, 0)

    F = jnp.cumsum(f_g, axis=1)                          # (B,L,H) Σ log f
    # pairwise log decay D[t,τ] = F_t − F_τ + ĩ_τ  (τ ≤ t)
    Dmat = F[:, :, None] - F[:, None, :] + i_g[:, None, :, :]   # (B,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dmat = jnp.where(tri[None, :, :, None], Dmat, _NEG)
    m_intra = jnp.max(Dmat, axis=2)                      # (B,L,H)
    m_inter = m0[:, None] + F                            # (B,L,H)
    m_t = jnp.maximum(m_inter, m_intra)

    # §Perf iteration (xlstm): the (B,L,L,H) pairwise tensors dominate the
    # memory term (they scale ∝ chunk — measured: growing the chunk does NOT
    # help).  With bf16 model inputs, keep them bf16 with f32 einsum
    # accumulation: ~2× less pairwise traffic; the stabilised weights
    # (|w_pair| ≤ 1) tolerate bf16.  f32 inputs keep the exact f32 path
    # (used by the step-vs-chunk equivalence tests).
    pair_dt = jnp.bfloat16 if orig_dtype == jnp.bfloat16 else jnp.float32
    w_pair = jnp.exp(Dmat - m_t[:, :, None]).astype(pair_dt)
    w_carry = jnp.exp(m_inter - m_t)                     # (B,L,H)

    qk = jnp.einsum("blhd,bthd->blth", q, k,
                    preferred_element_type=pair_dt)       # (B,L,L,H)
    y_num = jnp.einsum("blth,blth,bthv->blhv", qk, w_pair,
                       v.astype(pair_dt),
                       preferred_element_type=jnp.float32) \
        + w_carry[..., None] * jnp.einsum("bhdv,blhd->blhv", C0, q)
    n_t = jnp.einsum("blth,bthd->blhd", w_pair,
                     k.astype(pair_dt),
                     preferred_element_type=jnp.float32) \
        + w_carry[..., None] * n0[:, None]
    denom = jnp.maximum(jnp.abs(jnp.einsum("blhd,blhd->blh", n_t, q)),
                        jnp.exp(-m_t))
    y = y_num / denom[..., None]                         # (B,L,H,dv)

    # carry out (stabilized at m_out)
    m_out = m_t[:, -1]
    w_last = jnp.exp(Dmat[:, -1] - m_out[:, None])       # decay τ→L (B,L,H)
    wc_last = jnp.exp(m_inter[:, -1] - m_out)            # (B,H)
    C_new = wc_last[..., None, None] * C0 \
        + jnp.einsum("blh,blhd,blhv->bhdv", w_last, k, v)
    n_new = wc_last[..., None] * n0 \
        + jnp.einsum("blh,blhd->bhd", w_last, k)
    return (C_new, n_new, m_out), y


def mlstm_scan(q, k, v, i_g, f_g, state=None, chunk: int = MLSTM_CHUNK):
    """q,k: (B,T,H,dk); v: (B,T,H,dv); gates: (B,T,H).  → y (B,T,H,dv)."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = (jnp.zeros((B, H, dk, dv), jnp.float32),
                 jnp.zeros((B, H, dk), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))
    pad = (-T) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_g = jnp.pad(i_g, ((0, 0), (0, pad), (0, 0)), constant_values=_NEG)
        f_g = jnp.pad(f_g, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)
    Tp = q.shape[1]
    rs = lambda a: a.reshape(B, Tp // chunk, chunk, *a.shape[2:]).swapaxes(0, 1)
    body = jax.checkpoint(mlstm_chunk)
    state, ys = jax.lax.scan(body, state,
                             (rs(q), rs(k), rs(v), rs(i_g), rs(f_g)))
    y = ys.swapaxes(0, 1).reshape(B, Tp, H, dv)[:, :T]
    return y, state


def mlstm_step(q, k, v, i_g, f_g, state):
    """Single decode step.  q,k: (B,H,dk); v: (B,H,dv); gates (B,H)."""
    C0, n0, m0 = state
    dk = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dk, jnp.float32))
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32) * scale
    v = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_g.astype(jnp.float32))
    i_g = i_g.astype(jnp.float32)
    m_t = jnp.maximum(logf + m0, i_g)
    fp = jnp.exp(logf + m0 - m_t)
    ip = jnp.exp(i_g - m_t)
    C = fp[..., None, None] * C0 + ip[..., None, None] \
        * k[..., :, None] * v[..., None, :]
    n = fp[..., None] * n0 + ip[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_t))
    y = jnp.einsum("bhdv,bhd->bhv", C, q) / denom[..., None]
    return y, (C, n, m_t)


def mlstm_params_shapes(d_model: int, d_inner: int, n_heads: int
                        ) -> Dict[str, tuple]:
    dh = d_inner // n_heads
    return {
        "w_up": (d_model, 2 * d_inner),
        "w_conv": (d_inner, 4),
        "w_q": (d_inner, n_heads, dh),
        "w_k": (d_inner, n_heads, dh),
        "w_v": (d_inner, n_heads, dh),
        "w_gates": (d_model, 2 * n_heads),
        "b_gates": (2 * n_heads,),
        "w_down": (n_heads, dh, d_model),
    }


def mlstm_forward(p: Dict[str, Array], x: Array, state=None, decode=False,
                  chunk: int = MLSTM_CHUNK):
    """Full mLSTM block.  x: (B, T, D) → (y, new_state).

    state = (conv_state (B,3,di), (C, n, m)).
    """
    from repro.models import ssm as _ssm
    B, T, D = x.shape
    H = p["w_q"].shape[1]
    up = jnp.einsum("btd,de->bte", x, p["w_up"])
    u, z = jnp.split(up, 2, axis=-1)                     # (B,T,di)
    conv_state = state[0] if state is not None else None
    uc, conv_state = _ssm.causal_conv1d(u, p["w_conv"], conv_state)
    uc = jax.nn.silu(uc.astype(jnp.float32)).astype(x.dtype)
    q = jnp.einsum("btc,chd->bthd", uc, p["w_q"])
    k = jnp.einsum("btc,chd->bthd", uc, p["w_k"])
    v = jnp.einsum("btc,chd->bthd", u, p["w_v"])
    # NOTE (§Perf, refuted): constraining q/k/v's head-dim onto `model`
    # (heads=4 < TP=16) was measured to RAISE collective bytes 29% — the
    # pairwise-einsum psums outweigh the removed activation all-gathers.
    # Left unconstrained; GSPMD's gathers are the cheaper schedule here.
    gates = jnp.einsum("btd,dg->btg", x, p["w_gates"]) \
        + p["b_gates"].astype(x.dtype)
    i_g, f_g = jnp.split(gates, 2, axis=-1)              # (B,T,H)
    inner = state[1] if state is not None else None
    if decode:
        y, inner = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                              i_g[:, 0], f_g[:, 0], inner)
        y = y[:, None]                                   # (B,1,H,dv)
    else:
        y, inner = mlstm_scan(q, k, v, i_g, f_g, inner, chunk=chunk)
    y = y.astype(x.dtype) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype).reshape(B, T, H, -1)
    out = jnp.einsum("bthv,hvd->btd", y, p["w_down"])
    return out, (conv_state, inner)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_params_shapes(d_model: int, n_heads: int) -> Dict[str, tuple]:
    dh = d_model // n_heads
    return {
        "w_zifo": (d_model, 4 * d_model),
        "r_zifo": (4, n_heads, dh, dh),
        "b_zifo": (4 * d_model,),
        "w_out": (d_model, d_model),
    }


def slstm_step(p, x_t, state, n_heads: int):
    """x_t: (B, D); state = (h, c, n, m) each (B, D) f32."""
    h, c, n, m = state
    B, D = x_t.shape
    dh = D // n_heads
    zifo = jnp.einsum("bd,de->be", x_t, p["w_zifo"]).astype(jnp.float32) \
        + p["b_zifo"].astype(jnp.float32)
    hh = h.reshape(B, n_heads, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hh,
                     p["r_zifo"].astype(jnp.float32))    # (4,B,H,dh)
    rec = rec.reshape(4, B, D)
    z_, i_, f_, o_ = jnp.split(zifo, 4, axis=-1)
    z_ = jnp.tanh(z_ + rec[0])
    i_ = i_ + rec[1]
    f_ = f_ + rec[2]
    o_ = jax.nn.sigmoid(o_ + rec[3])
    logf = jax.nn.log_sigmoid(f_)
    m_t = jnp.maximum(logf + m, i_)
    fp = jnp.exp(logf + m - m_t)
    ip = jnp.exp(i_ - m_t)
    c_t = fp * c + ip * z_
    n_t = jnp.maximum(fp * n + ip, 1e-6)
    h_t = o_ * (c_t / n_t)
    return (h_t, c_t, n_t, m_t)


SLSTM_CHUNK = 256


def slstm_forward(p: Dict[str, Array], x: Array, state=None,
                  n_heads: int = 4, chunk: int = SLSTM_CHUNK):
    """x: (B, T, D) → (y, state).  Sequential scan (sLSTM is inherently so).

    §Perf iteration (xlstm): a flat scan over T makes reverse-mode save the
    four f32 (B, D) states for EVERY step (~17 GB/device at 4k/16 — the
    measured dominant traffic).  Chunking the scan and checkpointing each
    chunk saves only the per-chunk carries and recomputes inside the chunk
    on backward: T/chunk × (B,D) saves instead of T ×.
    """
    B, T, D = x.shape
    if state is None:
        z = jnp.zeros((B, D), jnp.float32)
        state = (z, z, z, jnp.full((B, D), -1e30, jnp.float32))

    def step(s, x_t):
        s = slstm_step(p, x_t, s, n_heads)
        return s, s[0].astype(x.dtype)

    @jax.checkpoint
    def chunk_body(s, xc):
        return jax.lax.scan(step, s, xc)

    n_full, rem = divmod(T, chunk)
    xt = x.swapaxes(0, 1)                                # (T, B, D)
    parts = []
    if n_full:
        xs = xt[:n_full * chunk].reshape(n_full, chunk, B, D)
        state, hs = jax.lax.scan(chunk_body, state, xs)
        parts.append(hs.reshape(n_full * chunk, B, D))
    if rem:
        # remainder processed unpadded — padded zero-steps would otherwise
        # keep evolving the recurrent state
        state, hs_r = chunk_body(state, xt[n_full * chunk:])
        parts.append(hs_r)
    y = jnp.concatenate(parts, axis=0).swapaxes(0, 1)    # (B, T, D)
    return jnp.einsum("btd,de->bte", y, p["w_out"]), state
