"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Keys/values are generated from a low-rank compressed latent c_kv (kv_lora
dims) plus a small shared RoPE key (qk_rope dims); the decode cache stores
only (c_kv ‖ k_rope) per token — (kv_lora + rope) floats instead of
2·H·head_dim — the architecture's whole point at 32k+ contexts.

Prefill uses the *materialized* form (k, v expanded; big MXU matmuls).
Decode uses the *absorbed* form: q is folded through W_uk once
(H·nope·kv_lora FLOPs) so attention scores are taken directly against the
compressed cache, and the attention context is folded through W_uv — per
token per layer this is O(H·T·(kv_lora+rope)) instead of
O(T·kv_lora·H·nope) for naive re-expansion of the whole cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Array = jax.Array


def mla_params_shapes(cfg) -> Dict[str, tuple]:
    d, h = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": (d, ql), "q_norm": (ql,),
        "w_uq": (ql, h, nope + rope),
        "w_dkv": (d, kvl), "kv_norm": (kvl,),
        "w_uk": (kvl, h, nope),
        "w_uv": (kvl, h, vh),
        "w_kr": (d, rope),
        "w_o": (h, vh, d),
    }


def _project_q(p, x, cfg, positions):
    cq = layers.rms_norm(jnp.einsum("btd,dq->btq", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("btq,qhe->bthe", cq, p["w_uq"])
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = layers.apply_rope(q[..., cfg.qk_nope_dim:], positions,
                               cfg.rope_theta)
    return q_nope, q_rope


def compress_kv(p, x, cfg, positions) -> Tuple[Array, Array]:
    """→ (c_kv (B,T,kvl), k_rope (B,T,rope)) — exactly what the cache holds."""
    ckv = layers.rms_norm(jnp.einsum("btd,dq->btq", x, p["w_dkv"]),
                          p["kv_norm"])
    k_rope = jnp.einsum("btd,dr->btr", x, p["w_kr"])
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions,
                               cfg.rope_theta)[:, :, 0]
    return ckv, k_rope


def mla_attention_full(p, x, cfg, positions, ckv, k_rope, k_pos,
                       k_valid=None) -> Array:
    """Materialized path (train/prefill).  ckv/k_rope cover the k-side."""
    q_nope, q_rope = _project_q(p, x, cfg, positions)
    k_nope = jnp.einsum("btq,qhe->bthe", ckv, p["w_uk"])
    v = jnp.einsum("btq,qhe->bthe", ckv, p["w_uv"])
    b, s = ckv.shape[:2]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (b, s, cfg.n_heads, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    attn = layers.attention(q, k, v, positions, k_pos, causal=True,
                            k_valid=k_valid)
    return jnp.einsum("bthv,hvd->btd", attn, p["w_o"])


def mla_attention_absorbed(p, x, cfg, positions, ckv_cache, krope_cache,
                           k_pos, k_valid) -> Array:
    """Absorbed decode path.  x: (B,1,D); caches: (B,S,·)."""
    q_nope, q_rope = _project_q(p, x, cfg, positions)     # (B,1,H,·)
    # fold q through W_uk: q̃ (B,1,H,kvl)
    q_lat = jnp.einsum("bthn,qhn->bthq", q_nope, p["w_uk"])
    scores = jnp.einsum("bthq,bsq->bhts", q_lat.astype(jnp.float32),
                        ckv_cache.astype(jnp.float32)) \
        + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                     krope_cache.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.asarray(
        cfg.qk_nope_dim + cfg.qk_rope_dim, jnp.float32))
    scores = scores * scale
    mask = (k_pos[:, None, :] <= positions[:, :, None])   # (B,1,S) causal
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bsq->bthq", probs,
                     ckv_cache.astype(jnp.float32))       # (B,1,H,kvl)
    attn = jnp.einsum("bthq,qhv->bthv", ctx.astype(x.dtype), p["w_uv"])
    return jnp.einsum("bthv,hvd->btd", attn, p["w_o"])
