"""Shared neural-net layers: norms, RoPE/M-RoPE, GQA attention (full / SWA /
cross), gated MLPs, embeddings.

Everything is a pure function over explicit parameter dicts (no module
framework): params are pytrees built by ``transformer.param_defs`` and
layer weights arrive stacked over the layer axis for ``lax.scan``.

Numerics: activations/params in cfg.dtype (bf16 by default), attention
logits+softmax and final logits in f32 — standard TPU recipe.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.distributed.sharding import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim/2,) inverse frequencies, f32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, T, H, hd); positions: (B, T) int32 → same shape, rotated."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B,T,half)
    cos = jnp.cos(angles)[:, :, None, :]                        # (B,T,1,half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: Tuple[int, ...]) -> Array:
    """Qwen2-VL multimodal RoPE.

    positions3: (3, B, T) — temporal / height / width position ids.  The
    head_dim/2 frequency slots are split into ``sections`` (t, h, w); each
    section rotates by its own position stream.  Text tokens carry identical
    t/h/w ids, reducing to vanilla RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    angle_streams = positions3[..., None].astype(jnp.float32) * freqs
    # (3, B, T, half) → pick stream per frequency slot
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)
    pick = jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32)  # (half,3)
    angles = jnp.einsum("sbth,hs->bth", angle_streams, pick)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; full / sliding-window / cross)
# ---------------------------------------------------------------------------

ATTN_KV_CHUNK = 512


def _attn_one_chunk(q, k, v, q_pos, k_pos, causal, window, k_valid, scale):
    """Un-chunked core: returns (unnormalised ctx, row max m, row sum l)."""
    b, t, kvh, g, hd = q.shape
    logits = jnp.einsum("btkgh,bskh->bkgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    dpos = q_pos[:, :, None] - k_pos[:, None, :]                 # (B, T, Sc)
    mask = jnp.ones(dpos.shape, bool)
    if causal:
        mask &= dpos >= 0
    window = jnp.asarray(window)
    mask &= (window <= 0) | (dpos < window)
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    m = jnp.max(logits, axis=-1)                                 # (B,KV,g,T)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    ctx = jnp.einsum("bkgts,bskh->btkgh", p.astype(v.dtype), v)
    return ctx, m, l


def attention(q: Array, k: Array, v: Array,
              q_pos: Array, k_pos: Array,
              causal: bool = True,
              window: Array | int = 0,
              k_valid: Optional[Array] = None,
              kv_chunk: int = ATTN_KV_CHUNK) -> Array:
    """Grouped-query attention with online-softmax chunking over keys.

    q: (B, T, H, hd);  k, v: (B, S, KV, hd);  q_pos: (B, T);  k_pos: (B, S).
    window: 0 → full; w > 0 → sliding window of width w.  May be a traced
    scalar (per-layer window pattern inside lax.scan).
    k_valid: (B, S) bool — mask for ring-buffer/padded cache slots.

    The key axis is processed in chunks with the running (max, sum, ctx)
    rescaling of flash attention, so the (T × S) logit matrix is never
    materialised — at 32k context the full matrix would be ~17 GB/device,
    far beyond HBM; chunking keeps the transient at T × kv_chunk.
    Returns (B, T, H, hd).
    """
    b, t, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, t, kvh, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    hd_v = v.shape[-1]
    # Decode (t == 1): never chunk.  The (B, H, 1, S) logits are tiny, and
    # chunking's (n_chunks, chunk, ...) reshape of an S-sharded cache forces
    # GSPMD into a full cache all-gather (§Perf iteration 2: this single
    # change removed ~95% of decode collective bytes).
    if s <= kv_chunk or t == 1:
        ctx, m, l = _attn_one_chunk(qg, k, v, q_pos, k_pos, causal, window,
                                    k_valid, scale)
        out = ctx.astype(jnp.float32) \
            / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.astype(q.dtype).reshape(b, t, h, hd_v)

    assert s % kv_chunk == 0, (s, kv_chunk)
    n_chunks = s // kv_chunk
    rs = lambda a: a.reshape(a.shape[0], n_chunks, kv_chunk,
                             *a.shape[2:]).swapaxes(0, 1)
    k_c, v_c = rs(k), rs(v)
    kp_c = k_pos.reshape(b, n_chunks, kv_chunk).swapaxes(0, 1)
    kv_valid_c = None if k_valid is None else \
        k_valid.reshape(b, n_chunks, kv_chunk).swapaxes(0, 1)

    m0 = jnp.full((b, kvh, g, t), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, t), jnp.float32)
    acc0 = jnp.zeros((b, t, kvh, g, hd_v), jnp.float32)

    def body(carry, chunk):
        m_run, l_run, acc = carry
        if k_valid is None:
            kc, vc, kpc = chunk
            kvc = None
        else:
            kc, vc, kpc, kvc = chunk
        ctx, m_c, l_c = _attn_one_chunk(qg, kc, vc, q_pos, kpc, causal,
                                        window, kvc, scale)
        m_new = jnp.maximum(m_run, m_c)
        a_old = jnp.exp(m_run - m_new)
        a_new = jnp.exp(m_c - m_new)
        l_new = l_run * a_old + l_c * a_new
        acc = acc * a_old.transpose(0, 3, 1, 2)[..., None] \
            + ctx.astype(jnp.float32) * a_new.transpose(0, 3, 1, 2)[..., None]
        return (m_new, l_new, acc), None

    chunks = (k_c, v_c, kp_c) if k_valid is None \
        else (k_c, v_c, kp_c, kv_valid_c)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, acc0), chunks)
    out = acc / jnp.maximum(l_f, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype).reshape(b, t, h, hd_v)


def gqa_project(x: Array, wq: Array, wk: Array, wv: Array,
                qk_norm_scales: Optional[Tuple[Array, Array]] = None
                ) -> Tuple[Array, Array, Array]:
    """x: (B,T,D) → q (B,T,H,hd), k/v (B,T,KV,hd)."""
    q = jnp.einsum("btd,dhk->bthk", x, wq)
    k = jnp.einsum("btd,dhk->bthk", x, wk)
    v = jnp.einsum("btd,dhk->bthk", x, wv)
    if qk_norm_scales is not None:
        q = rms_norm(q, qk_norm_scales[0])
        k = rms_norm(k, qk_norm_scales[1])
    return q, k, v


def attn_out(attn: Array, wo: Array) -> Array:
    return jnp.einsum("bthk,hkd->btd", attn, wo)


# Global attention implementation switch for the TRAIN/PREFILL-no-cache
# path: "xla" (chunked online-softmax above) or "flash" (Pallas kernel,
# kernels/flash_attention.py — §Perf iteration on the train cells).
ATTN_IMPL = "xla"


def attention_trainpath(q: Array, k: Array, v: Array, q_pos: Array,
                        k_pos: Array, window: Array | int = 0) -> Array:
    """Causal self-attention for the no-cache path, honouring ATTN_IMPL.

    Flash path: GQA kv heads are expanded to the q heads (a cheap gather —
    after tensor-parallel sharding the per-device q-head count is small),
    then the Pallas kernel runs per device inside shard_map.
    """
    if ATTN_IMPL != "flash":
        return attention(q, k, v, q_pos, k_pos, causal=True, window=window)
    from repro.distributed.sharding import active_mesh, resolve_spec
    from repro.kernels.flash_attention import flash_attention
    import functools
    from jax.sharding import PartitionSpec as P

    b, t, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    idx = jnp.arange(h) // g
    k = jnp.take(k, idx, axis=2)                    # (B, S, H, hd)
    v = jnp.take(v, idx, axis=2)
    interp = jax.default_backend() != "tpu"
    win = jnp.asarray(window, jnp.int32)

    mesh = active_mesh()
    if mesh is None:
        return flash_attention(q, k, v, q_pos, k_pos, win,
                               causal=True, interpret=interp)
    qs = resolve_spec(("batch", None, "heads", None), q.shape)
    ps = resolve_spec(("batch", None), q_pos.shape)
    fn = compat.shard_map(
        functools.partial(flash_attention, causal=True, interpret=interp),
        mesh=mesh,
        in_specs=(qs, qs, qs, ps, ps, P()),
        out_specs=qs)
    return fn(q, k, v, q_pos, k_pos, win)


# ---------------------------------------------------------------------------
# Gated MLPs
# ---------------------------------------------------------------------------

def gated_mlp(x: Array, w_gate: Array, w_up: Array, w_down: Array,
              act: str = "silu") -> Array:
    """SwiGLU (act=silu) / GeGLU (act=gelu): down(act(gate(x)) * up(x))."""
    g = jnp.einsum("btd,df->btf", x, w_gate)
    u = jnp.einsum("btd,df->btf", x, w_up)
    g = constrain(g, ("batch", None, "mlp"))
    u = constrain(u, ("batch", None, "mlp"))
    if act == "gelu":
        h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(x.dtype) * u
    else:
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("btf,fd->btd", h, w_down)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed(tokens: Array, table: Array, scale: bool = False) -> Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.sqrt(jnp.asarray(table.shape[1], jnp.float32)).astype(x.dtype)
    return x


def unembed(x: Array, table_or_head: Array, tied: bool) -> Array:
    """→ f32 logits.  tied: table is (V, D); untied: head is (D, V)."""
    if tied:
        return jnp.einsum("btd,vd->btv", x, table_or_head,
                          preferred_element_type=jnp.float32)
    return jnp.einsum("btd,dv->btv", x, table_or_head,
                      preferred_element_type=jnp.float32)
