"""Selective state-space (Mamba-1 style) block — the SSM branch of Hymba.

Diagonal selective SSM:   h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t,
                          y_t = C_tᵀ h_t + D_skip x_t
with input-dependent Δ, B, C and a depthwise causal conv front-end.

TPU mapping: the recurrence is a *chunked scan* — an outer ``lax.scan`` over
sequence chunks carries the (B, d_inner, N) state, an inner associative scan
parallelises within the chunk, and the (B, Tc, d_inner, N) intermediate is
consumed inside the chunk (only y leaves), keeping transient VMEM/HBM
pressure to one chunk.  ``jax.checkpoint`` on the chunk body bounds backward
memory the same way.

Decode is the O(1)-per-token recurrent step on carried (conv_state, h).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

CHUNK = 32


def _ssm_chunk(h0: Array, a: Array, b: Array, c: Array) -> Tuple[Array, Array]:
    """One chunk of the diagonal recurrence.

    h0: (B, C, N);  a, b: (B, Tc, C, N) decay / input;  c: (B, Tc, N).
    Returns (h_last, y) with y: (B, Tc, C).
    """
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b2 + a2 * b1

    aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
    h = bb + aa * h0[:, None]                       # (B, Tc, C, N)
    y = jnp.einsum("btcn,btn->btc", h, c)
    return h[:, -1], y


def ssm_scan(a: Array, b: Array, c: Array, h0: Array,
             chunk: int = CHUNK) -> Tuple[Array, Array]:
    """Full-sequence scan.  a, b: (B, T, C, N); c: (B, T, N); h0: (B, C, N).

    Returns (y: (B, T, C), h_final).
    """
    B, T, Ch, N = a.shape
    if T % chunk:
        pad = chunk - T % chunk
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Tp = a.shape[1]
    a = a.reshape(B, Tp // chunk, chunk, Ch, N).swapaxes(0, 1)
    b = b.reshape(B, Tp // chunk, chunk, Ch, N).swapaxes(0, 1)
    c = c.reshape(B, Tp // chunk, chunk, N).swapaxes(0, 1)

    body = jax.checkpoint(lambda h, abc: _ssm_chunk(h, *abc))
    h_final, ys = jax.lax.scan(lambda h, abc: body(h, abc), h0, (a, b, c))
    y = ys.swapaxes(0, 1).reshape(B, Tp, Ch)[:, :T]
    return y, h_final


def causal_conv1d(x: Array, w: Array, state: Optional[Array] = None
                  ) -> Tuple[Array, Array]:
    """Depthwise causal conv.  x: (B, T, C); w: (C, K).

    state: (B, K-1, C) carried context for streaming; returns (y, new_state).
    """
    B, T, C = x.shape
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)        # (B, T+K-1, C)
    y = jnp.zeros((B, T, C), jnp.float32)
    for i in range(K):                              # K is tiny (4)
        y = y + xx[:, i:i + T].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return y.astype(x.dtype), xx[:, -(K - 1):] if K > 1 else state


def mamba_params_shapes(d_model: int, d_inner: int, n_state: int,
                        conv_k: int, dt_rank: int) -> Dict[str, tuple]:
    return {
        "w_in": (d_model, 2 * d_inner),
        "w_conv": (d_inner, conv_k),
        "w_xproj": (d_inner, dt_rank + 2 * n_state),
        "w_dt": (dt_rank, d_inner),
        "b_dt": (d_inner,),
        "a_log": (d_inner, n_state),
        "d_skip": (d_inner,),
        "w_out": (d_inner, d_model),
    }


def mamba_forward(p: Dict[str, Array], x: Array,
                  state: Optional[Tuple[Array, Array]] = None,
                  dt_rank: int = 0, n_state: int = 16
                  ) -> Tuple[Array, Tuple[Array, Array]]:
    """x: (B, T, D) → (y (B, T, D), (conv_state, h_state)).

    state = (conv_state (B, K-1, di), h (B, di, N)); None = zeros (training).
    """
    B, T, _ = x.shape
    di = p["w_out"].shape[0]
    xz = jnp.einsum("btd,de->bte", x, p["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)               # (B, T, di) each
    conv_state = state[0] if state is not None else None
    xi, conv_state = causal_conv1d(xi, p["w_conv"], conv_state)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("btc,ce->bte", xi, p["w_xproj"])
    dt_in, Bt, Ct = jnp.split(
        proj, [dt_rank, dt_rank + n_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rc->btc", dt_in, p["w_dt"]).astype(jnp.float32)
        + p["b_dt"].astype(jnp.float32))            # (B, T, di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))    # (di, N)
    a = jnp.exp(dt[..., None] * A[None, None])      # (B, T, di, N)
    b = (dt * xi.astype(jnp.float32))[..., None] \
        * Bt.astype(jnp.float32)[:, :, None, :]     # (B, T, di, N)

    h0 = state[1] if state is not None \
        else jnp.zeros((B, di, n_state), jnp.float32)
    y, h = ssm_scan(a, b, Ct.astype(jnp.float32), h0)
    y = y + xi.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("btc,cd->btd", y, p["w_out"])
    return out, (conv_state, h)
