"""repro.models — config-driven model zoo for the 10 assigned architectures."""
