"""Mixture-of-Experts FFN with expert parallelism over the `model` mesh axis.

Three execution paths, chosen by token count and mesh:

* ``alltoall`` (training / prefill): tokens are additionally split over the
  `model` axis (sequence sharding), each device routes its local tokens into
  a capacity-bounded (E, C, D) dispatch buffer via a sort-based scatter (no
  (tokens × E × C) one-hot einsum — that tensor is ~200× the activations at
  our shapes), then one ``all_to_all`` exchanges the expert↔token dims so
  each device runs only its E/m local experts, and a second ``all_to_all``
  brings results home.  This is the GShard/DeepSpeed-EP pattern expressed as
  jax collectives inside shard_map.

* ``psum`` (decode): token counts are tiny (one per sequence), so dispatch
  buffers and a2a latency dominate.  Instead every device computes its local
  experts' contribution for all (replicated) tokens, masked by the routing,
  and one ``psum`` over `model` combines.  FLOPs are wasted on unrouted
  (token, expert) pairs, but decode is weight-streaming-bound, not
  FLOPs-bound, so this is the faster schedule.

* ``dense`` (single-device smoke tests): plain masked einsum over all
  experts.

Experts are padded to a multiple of the `model` axis size (router logits of
padding experts pinned to −inf) so any expert count maps onto any mesh.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed import sharding as shd

Array = jax.Array


def pad_experts(n_experts: int, model_parallel: int) -> int:
    return -(-n_experts // max(model_parallel, 1)) * max(model_parallel, 1)


# ---------------------------------------------------------------------------
# Routing (local, sort-based dispatch)
# ---------------------------------------------------------------------------

def route(x: Array, w_router: Array, n_real: int, top_k: int
          ) -> Tuple[Array, Array]:
    """x: (N, D) → (gates (N, k) f32, expert ids (N, k) i32)."""
    logits = jnp.einsum("nd,de->ne", x, w_router,
                        preferred_element_type=jnp.float32)
    e_pad = w_router.shape[1]
    if n_real < e_pad:
        mask = jnp.arange(e_pad) < n_real
        logits = jnp.where(mask[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx


def dispatch_indices(eidx: Array, n_experts: int, capacity: int):
    """Sort-based positions: for each (token, k) slot, its position within
    its expert's capacity buffer.  Returns (dest (N*k,), keep (N*k,), order).
    Dropped tokens (beyond capacity) get dest == E*C (an overflow row)."""
    flat_e = eidx.reshape(-1)
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    seg_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(nk, dtype=jnp.int32) - seg_start[sorted_e].astype(jnp.int32)
    keep_sorted = pos < capacity
    dest_sorted = jnp.where(keep_sorted,
                            sorted_e * capacity + pos, n_experts * capacity)
    inv = jnp.argsort(order, stable=True)
    return dest_sorted[inv], keep_sorted[inv], order


def _expert_ffn(buf: Array, w_gate: Array, w_up: Array, w_down: Array,
                act: str) -> Array:
    """buf: (E_l, C', D); weights (E_l, D, F) / (E_l, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    if act == "gelu":
        h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(buf.dtype) * u
    else:
        h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ---------------------------------------------------------------------------
# Local (per-device) dispatch → compute → combine, used by both shard paths
# ---------------------------------------------------------------------------

def _dispatch_local(x2: Array, gates: Array, eidx: Array, e_pad: int,
                    capacity: int) -> Tuple[Array, Array, Array]:
    n, d = x2.shape
    k = eidx.shape[1]
    dest, keep, _ = dispatch_indices(eidx, e_pad, capacity)
    src_tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    buf = jnp.zeros((e_pad * capacity + 1, d), x2.dtype)
    buf = buf.at[dest].set(x2[src_tok], mode="drop")
    return buf[:-1].reshape(e_pad, capacity, d), dest, keep


def _combine_local(out_buf: Array, gates: Array, dest: Array, keep: Array,
                   n: int, d: int) -> Array:
    k = gates.shape[1]
    flat = out_buf.reshape(-1, d)
    flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], axis=0)
    y = flat[jnp.minimum(dest, flat.shape[0] - 1)]
    live = (keep & (dest < flat.shape[0] - 1))[:, None]
    y = y * live.astype(y.dtype)
    y = y.reshape(n, k, d) * gates[..., None].astype(y.dtype)
    return jnp.sum(y, axis=1)


# ---------------------------------------------------------------------------
# Public paths
# ---------------------------------------------------------------------------

def moe_dense(p: Dict[str, Array], x: Array, n_real: int, top_k: int,
              act: str = "silu") -> Array:
    """All-experts masked einsum — smoke tests / 1 device.  x: (B,T,D)."""
    b, t, d = x.shape
    x2 = x.reshape(-1, d)
    gates, eidx = route(x2, p["w_router"], n_real, top_k)
    e_pad = p["w_router"].shape[1]
    onehot = jax.nn.one_hot(eidx, e_pad, dtype=jnp.float32)      # (N,k,E)
    comb = jnp.einsum("nk,nke->ne", gates, onehot).astype(x.dtype)
    h = _expert_ffn(jnp.broadcast_to(x2[None], (e_pad, x2.shape[0], d)),
                    p["w_gate"], p["w_up"], p["w_down"], act)    # (E,N,D)
    y = jnp.einsum("ne,end->nd", comb, h)
    return y.reshape(b, t, d)


def moe_alltoall_local(p_local: Dict[str, Array], x_local: Array,
                       n_real: int, top_k: int, capacity_factor: float,
                       act: str, axis: str = "model") -> Array:
    """shard_map body.  x_local: (B_l, T_l, D) — tokens already split over
    data AND model axes.  p_local experts: (E/m, D, F); router replicated."""
    m = compat.axis_size(axis)
    b, t, d = x_local.shape
    n = b * t
    e_pad = p_local["w_router"].shape[1]
    x2 = x_local.reshape(n, d)
    gates, eidx = route(x2, p_local["w_router"], n_real, top_k)
    capacity = max(int(capacity_factor * n * top_k / e_pad), 1)
    buf, dest, keep = _dispatch_local(x2, gates, eidx, e_pad, capacity)
    # (E, C, D) → (E/m, m·C, D): expert dim scattered, token dim gathered.
    buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                             tiled=True)
    out = _expert_ffn(buf, p_local["w_gate"], p_local["w_up"],
                      p_local["w_down"], act)
    out = jax.lax.all_to_all(out, axis, split_axis=1, concat_axis=0,
                             tiled=True)
    y = _combine_local(out, gates, dest, keep, n, d)
    return y.reshape(b, t, d)


def moe_psum_local(p_local: Dict[str, Array], x_local: Array,
                   n_real: int, top_k: int, act: str,
                   axis: str = "model") -> Array:
    """shard_map decode body.  x_local: (B_l, T, D) replicated over `axis`;
    every device computes its local experts densely and psums."""
    m = compat.axis_size(axis)
    me = jax.lax.axis_index(axis)
    b, t, d = x_local.shape
    e_pad = p_local["w_router"].shape[1]
    e_local = p_local["w_gate"].shape[0]
    x2 = x_local.reshape(-1, d)
    gates, eidx = route(x2, p_local["w_router"], n_real, top_k)
    # combine weight for each LOCAL expert: (N, E_l)
    local_ids = me * e_local + jnp.arange(e_local)
    onehot = (eidx[..., None] == local_ids[None, None, :])
    comb = jnp.einsum("nk,nke->ne", gates,
                      onehot.astype(jnp.float32)).astype(x_local.dtype)
    h = _expert_ffn(jnp.broadcast_to(x2[None], (e_local, x2.shape[0], d)),
                    p_local["w_gate"], p_local["w_up"], p_local["w_down"],
                    act)                                          # (E_l,N,D)
    y = jnp.einsum("ne,end->nd", comb, h)
    y = jax.lax.psum(y, axis)
    return y.reshape(b, t, d)


def moe_ffn(p: Dict[str, Array], x: Array, *, n_real: int, top_k: int,
            capacity_factor: float, act: str, decode: bool) -> Array:
    """Dispatching wrapper: picks dense / alltoall / psum by mesh & shape.

    Expert weights in ``p`` are globally shaped (E_pad, D, F); sharding of
    the expert axis over `model` comes from the parameter specs, and the
    shard_map in_specs below slice them accordingly.
    """
    mesh = shd.active_mesh()
    if mesh is None or "model" not in mesh.shape or mesh.shape["model"] == 1:
        return moe_dense(p, x, n_real, top_k, act)
    m = mesh.shape["model"]
    b, t, d = x.shape
    expert_specs = {
        "w_router": P(), "w_gate": P("model"), "w_up": P("model"),
        "w_down": P("model"),
    }
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not decode and t % m == 0 and t // m >= 1:
        fn = compat.shard_map(
            functools.partial(moe_alltoall_local, n_real=n_real,
                              top_k=top_k, capacity_factor=capacity_factor,
                              act=act),
            mesh=mesh,
            in_specs=(expert_specs, P(data_axes, "model")),
            out_specs=P(data_axes, "model"))
        return fn(p, x)
    fn = compat.shard_map(
        functools.partial(moe_psum_local, n_real=n_real, top_k=top_k,
                          act=act),
        mesh=mesh,
        in_specs=(expert_specs, P(data_axes)),
        out_specs=P(data_axes))
    return fn(p, x)
