"""Model configuration — one dataclass covers all 10 assigned families.

Families: dense (llama-like GQA), moe (GShard-style EP), mla (MiniCPM3 /
DeepSeek-style multi-head latent attention), hybrid (Hymba parallel
attention‖Mamba heads), ssm (xLSTM mLSTM/sLSTM stacks), encdec
(SeamlessM4T backbone), vlm (Qwen2-VL backbone, M-RoPE + patch-embed stub),
audio == encdec with a frame-embedding stub frontend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense|moe|mla|hybrid|ssm|encdec|vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    vocab_size: int = 1024
    act: str = "silu"            # silu → SwiGLU, gelu → GeGLU
    rope_theta: float = 10000.0
    rope_style: str = "rope"     # rope | mrope | none
    # attention
    attn_kind: str = "full"      # full | swa (per-layer pattern below)
    window: int = 0              # SWA window size (0 = no SWA anywhere)
    # indices of layers that use FULL attention when attn_kind == "swa"
    global_layers: Tuple[int, ...] = ()
    qk_norm: bool = False
    embed_scale: bool = False    # gemma-style sqrt(d_model) embedding scale
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1           # every k-th layer is MoE (1 = all)
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM / hybrid / xLSTM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0         # xLSTM: every k-th layer is sLSTM (0 = none)
    # mLSTM chunk length: larger chunks round-trip the (H, dk, dv) matrix
    # state through HBM fewer times per token (§Perf iteration 3).
    mlstm_chunk: int = 64
    # encoder-decoder
    n_enc_layers: int = 0
    # M-RoPE sections (t, h, w) — must sum to head_dim // 2
    mrope_sections: Tuple[int, ...] = ()
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # long-context capability flag (set for swa/ssm/hybrid archs)
    supports_long_context: bool = False

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.family == "mla":
            ql, kvl = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vh = self.qk_nope_dim, self.qk_rope_dim, self.v_head_dim
            attn = d * ql + ql * h * (nope + rope) \
                + d * (kvl + rope) + kvl * h * (nope + vh) + h * vh * d
        mlp = 3 * d * f
        if self.n_experts:
            moe_mlp = self.n_experts * 3 * d * f + d * self.n_experts
            n_moe = self.n_layers // self.moe_every
            mlp = (moe_mlp * n_moe + 3 * d * f * (self.n_layers - n_moe)) \
                / max(self.n_layers, 1)
        block = attn + mlp + 2 * d
        if self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            block += 2 * d * di + di * self.ssm_conv + di * (2 * n + 2) + di * d
        if self.family == "ssm":
            # mLSTM projections dominate; rough: qkv+gates+out
            di = self.d_inner
            block = 2 * d * di + 3 * di * di // max(self.n_heads, 1) + di * d \
                + 2 * d
        layers = self.n_layers + self.n_enc_layers
        total = block * layers + v * d + (0 if self.tie_embeddings else v * d)
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_equiv = dataclasses.replace(self, n_experts=0, top_k=0)
        n_moe = self.n_layers // self.moe_every
        # swap each MoE layer's expert bank for top_k experts' worth
        return int(dense_equiv.n_params()
                   - n_moe * 3 * d * f + n_moe * self.top_k * 3 * d * f)
