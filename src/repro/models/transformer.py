"""Model assembly for all 10 assigned architectures.

One config-driven implementation: parameter trees are built from
``param_defs`` (a single source of truth yielding real init, abstract
ShapeDtypeStructs and PartitionSpecs), layers are stacked on a leading L axis
and executed with ``lax.scan`` (keeps HLO size O(1) in depth — essential for
compiling 80-layer models), per-layer heterogeneity (SWA windows, global
layers, sLSTM positions) is expressed as scanned per-layer scalar arrays.

Entry points:
  ``loss_fn``      — causal (or seq2seq) LM loss for training
  ``prefill``      — run the prompt, build the decode cache
  ``decode_step``  — one token with cache (full / ring-buffer / MLA-latent /
                     SSM-state caches per family)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain, resolve_spec
from repro.models import layers, mla, moe, ssm, xlstm
from repro.models.config import ModelConfig

Array = jax.Array

# =========================================================================
# Parameter definitions
# =========================================================================

def _mk(shape, axes, scale=0.02, kind="normal"):
    return {"shape": tuple(shape), "axes": tuple(axes), "scale": scale,
            "kind": kind}


def _attn_defs(cfg: ModelConfig, L: int) -> Dict[str, dict]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": _mk((L, d, h, hd), ("layers", "fsdp", "heads", None)),
        "wk": _mk((L, d, kv, hd), ("layers", "fsdp", "kv_heads", None)),
        "wv": _mk((L, d, kv, hd), ("layers", "fsdp", "kv_heads", None)),
        "wo": _mk((L, h, hd, d), ("layers", "heads", None, "fsdp")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = _mk((L, hd), ("layers", None), kind="zeros")
        defs["k_norm"] = _mk((L, hd), ("layers", None), kind="zeros")
    return defs


def _mlp_defs(cfg: ModelConfig, L: int) -> Dict[str, dict]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": _mk((L, d, f), ("layers", "fsdp", "mlp")),
        "w_up": _mk((L, d, f), ("layers", "fsdp", "mlp")),
        "w_down": _mk((L, f, d), ("layers", "mlp", "fsdp")),
    }


def _moe_defs(cfg: ModelConfig, L: int, e_pad: int) -> Dict[str, dict]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_router": _mk((L, d, e_pad), ("layers", "fsdp", None)),
        "w_gate": _mk((L, e_pad, d, f), ("layers", "experts", "fsdp", None)),
        "w_up": _mk((L, e_pad, d, f), ("layers", "experts", "fsdp", None)),
        "w_down": _mk((L, e_pad, f, d), ("layers", "experts", None, "fsdp")),
    }


def _mla_defs(cfg: ModelConfig, L: int) -> Dict[str, dict]:
    shapes = mla.mla_params_shapes(cfg)
    axes = {
        "w_dq": ("fsdp", None), "q_norm": (None,),
        "w_uq": (None, "heads", None),
        "w_dkv": ("fsdp", None), "kv_norm": (None,),
        "w_uk": (None, "heads", None), "w_uv": (None, "heads", None),
        "w_kr": ("fsdp", None), "w_o": ("heads", None, "fsdp"),
    }
    out = {}
    for k, shp in shapes.items():
        kind = "zeros" if k.endswith("norm") else "normal"
        out[k] = _mk((L,) + shp, ("layers",) + axes[k], kind=kind)
    return out


def _mamba_defs(cfg: ModelConfig, L: int) -> Dict[str, dict]:
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = max(cfg.d_model // 16, 1)
    shapes = ssm.mamba_params_shapes(cfg.d_model, di, n, cfg.ssm_conv,
                                     dt_rank)
    axes = {
        "w_in": ("fsdp", "mlp"), "w_conv": ("mlp", None),
        "w_xproj": ("mlp", None), "w_dt": (None, "mlp"),
        "b_dt": ("mlp",), "a_log": ("mlp", None), "d_skip": ("mlp",),
        "w_out": ("mlp", "fsdp"),
    }
    kinds = {"a_log": "a_log", "b_dt": "dt_bias", "d_skip": "ones"}
    return {k: _mk((L,) + shp, ("layers",) + axes[k],
                   kind=kinds.get(k, "normal"))
            for k, shp in shapes.items()}


def _mlstm_defs(cfg: ModelConfig, shape_prefix, axes_prefix) -> Dict[str, dict]:
    shapes = xlstm.mlstm_params_shapes(cfg.d_model, cfg.d_inner, cfg.n_heads)
    axes = {
        "w_up": ("fsdp", "mlp"), "w_conv": ("mlp", None),
        "w_q": ("mlp", "heads", None), "w_k": ("mlp", "heads", None),
        "w_v": ("mlp", "heads", None), "w_gates": ("fsdp", None),
        "b_gates": (None,), "w_down": ("heads", None, "fsdp"),
    }
    kinds = {"b_gates": "gate_bias"}
    return {k: _mk(shape_prefix + shp, axes_prefix + axes[k],
                   kind=kinds.get(k, "normal"))
            for k, shp in shapes.items()}


def _slstm_defs(cfg: ModelConfig, shape_prefix, axes_prefix) -> Dict[str, dict]:
    shapes = xlstm.slstm_params_shapes(cfg.d_model, cfg.n_heads)
    axes = {
        "w_zifo": ("fsdp", None), "r_zifo": (None, "heads", None, None),
        "b_zifo": (None,), "w_out": ("fsdp", None),
    }
    return {k: _mk(shape_prefix + shp, axes_prefix + axes[k])
            for k, shp in shapes.items()}


def _block_defs(cfg: ModelConfig, L: int, cross: bool = False
                ) -> Dict[str, Any]:
    """Per-layer defs for one decoder/encoder stack of the given family."""
    e_pad = moe.pad_experts(cfg.n_experts, _model_axis_size()) \
        if cfg.n_experts else 0
    defs: Dict[str, Any] = {
        "ln1": _mk((L, cfg.d_model), ("layers", None), kind="zeros"),
        "ln2": _mk((L, cfg.d_model), ("layers", None), kind="zeros"),
    }
    if cfg.family == "mla":
        defs["attn"] = _mla_defs(cfg, L)
        defs["mlp"] = _mlp_defs(cfg, L)
    elif cfg.family == "ssm":
        pass  # handled by grouped defs in param_defs
    else:
        defs["attn"] = _attn_defs(cfg, L)
        if cfg.n_experts:
            defs["moe"] = _moe_defs(cfg, L, e_pad)
        else:
            defs["mlp"] = _mlp_defs(cfg, L)
    if cfg.family == "hybrid":
        defs["mamba"] = _mamba_defs(cfg, L)
        defs["ln_ssm"] = _mk((L, cfg.d_model), ("layers", None), kind="zeros")
    if cross:
        defs["xattn"] = _attn_defs(cfg, L)
        defs["ln_x"] = _mk((L, cfg.d_model), ("layers", None), kind="zeros")
    return defs


def _model_axis_size() -> int:
    from repro.distributed.sharding import active_mesh
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.shape:
        return 1
    return mesh.shape["model"]


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    defs: Dict[str, Any] = {
        "embed": _mk((v, d), ("vocab", "fsdp"), scale=1.0),
        "final_norm": _mk((d,), (None,), kind="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = _mk((d, v), ("fsdp", "vocab"))
    if cfg.family == "ssm":
        # xLSTM: groups of (slstm_every-1) mLSTM layers + 1 sLSTM layer.
        per = cfg.slstm_every if cfg.slstm_every else cfg.n_layers
        groups = cfg.n_layers // per
        m_per = per - (1 if cfg.slstm_every else 0)
        defs["mlstm"] = {
            "blk": _mlstm_defs(cfg, (groups, m_per),
                               ("layers", "layers")),
            "ln": _mk((groups, m_per, d), ("layers", "layers", None),
                      kind="zeros"),
        }
        if cfg.slstm_every:
            defs["slstm"] = {
                "blk": _slstm_defs(cfg, (groups,), ("layers",)),
                "ln": _mk((groups, d), ("layers", None), kind="zeros"),
            }
    else:
        defs["blocks"] = _block_defs(cfg, cfg.n_layers,
                                     cross=cfg.is_encdec)
    if cfg.is_encdec:
        defs["enc_blocks"] = _block_defs(
            dataclasses.replace(cfg, n_experts=0, family="dense"),
            cfg.n_enc_layers)
        defs["enc_norm"] = _mk((d,), (None,), kind="zeros")
    return defs


# ---- materialisation ----------------------------------------------------

def _init_leaf(key, leaf: dict, dtype) -> Array:
    shape, kind, scale = leaf["shape"], leaf["kind"], leaf["scale"]
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "a_log":
        n = shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), shape)
        return jnp.log(a).astype(dtype)
    if kind == "dt_bias":
        u = jax.random.uniform(key, shape, jnp.float32,
                               minval=np.log(1e-3), maxval=np.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)  # inv softplus
    if kind == "gate_bias":
        h2 = shape[-1]
        b = jnp.concatenate([jnp.zeros((h2 // 2,)),       # input gates
                             jnp.linspace(3.0, 6.0, h2 - h2 // 2)])
        return jnp.broadcast_to(b, shape).astype(dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = min(scale, 1.0 / np.sqrt(max(fan_in, 1)))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def _is_leaf(x) -> bool:
    return isinstance(x, dict) and "shape" in x and "axes" in x


def init_params(cfg: ModelConfig, key: Array) -> Dict[str, Any]:
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, leaf, cfg.param_dtype)
            for k, leaf in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig) -> Dict[str, Any]:
    defs = param_defs(cfg)
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l["shape"], cfg.param_dtype),
        defs, is_leaf=_is_leaf)


def param_pspecs(cfg: ModelConfig) -> Dict[str, Any]:
    """PartitionSpec tree (resolved under the active mesh/rules)."""
    defs = param_defs(cfg)
    return jax.tree.map(
        lambda l: resolve_spec(l["axes"], l["shape"]), defs, is_leaf=_is_leaf)


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# =========================================================================
# Per-layer window pattern
# =========================================================================

def layer_windows(cfg: ModelConfig, override_window: int = 0) -> np.ndarray:
    """(L,) int32: 0 = full attention, w>0 = sliding window of w."""
    L = cfg.n_layers
    if override_window:
        return np.full((L,), override_window, np.int32)
    if cfg.attn_kind == "swa" and cfg.window:
        w = np.full((L,), cfg.window, np.int32)
        for g in cfg.global_layers:
            w[g] = 0
        return w
    return np.zeros((L,), np.int32)


# =========================================================================
# Block forwards
# =========================================================================

def _self_attn(p, x, q_pos, k, v, k_pos, window, k_valid, causal=True):
    """Post-projection attention + output proj.  k/v already positioned."""
    attn = layers.attention(jnp.einsum("btd,dhk->bthk", x, p["wq"])
                            if False else x,  # placeholder, unused
                            k, v, q_pos, k_pos)
    raise AssertionError("unused")


def _dense_attn_block(p, x, positions, cfg: ModelConfig, window,
                      kv_cache=None, cache_idx=None, positions3=None):
    """Self-attention with optional cache.  Returns (out, new_kv_slices).

    kv_cache: None (train) or dict with k/v (B,Sc,KV,hd), pos (B,Sc).
    """
    qkn = (p.get("q_norm"), p.get("k_norm")) if cfg.qk_norm else None
    q, k, v = layers.gqa_project(x, p["wq"], p["wk"], p["wv"],
                                 qk_norm_scales=qkn)
    if cfg.rope_style == "mrope":
        q = layers.apply_mrope(q, positions3, cfg.rope_theta,
                               cfg.mrope_sections)
        k = layers.apply_mrope(k, positions3, cfg.rope_theta,
                               cfg.mrope_sections)
    elif cfg.rope_style == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))

    if kv_cache is None:
        attn = layers.attention_trainpath(q, k, v, positions, positions,
                                          window=window)
        new_cache = None
    else:
        sc = kv_cache["k"].shape[1]
        b, t = x.shape[0], x.shape[1]
        slot = jnp.mod(cache_idx[:, None] + jnp.arange(t)[None], sc)
        rows = jnp.arange(b)[:, None]
        k_all = kv_cache["k"].at[rows, slot].set(
            k.astype(kv_cache["k"].dtype))
        v_all = kv_cache["v"].at[rows, slot].set(
            v.astype(kv_cache["v"].dtype))
        pos_all = kv_cache["pos"].at[rows, slot].set(positions)
        valid = pos_all >= 0
        attn = layers.attention(q, k_all.astype(q.dtype),
                                v_all.astype(q.dtype),
                                positions, pos_all, causal=True,
                                window=window, k_valid=valid)
        new_cache = {"k": k_all, "v": v_all, "pos": pos_all}
    return layers.attn_out(attn, p["wo"]), new_cache


def _ffn(pblk, x, cfg: ModelConfig, decode: bool):
    if "moe" in pblk:
        return moe.moe_ffn(pblk["moe"], x, n_real=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           act=cfg.act, decode=decode)
    m = pblk["mlp"]
    return layers.gated_mlp(x, m["w_gate"], m["w_up"], m["w_down"], cfg.act)


def _decoder_block(pblk, x, positions, cfg: ModelConfig, window,
                   kv_cache=None, cache_idx=None, positions3=None,
                   mamba_state=None, enc_out=None, xattn_cache=None,
                   enc_positions=None, decode=False):
    """One transformer block (all non-xLSTM families).

    Returns (x, new_kv_cache, new_mamba_state, new_xattn_cache).
    """
    h = layers.rms_norm(x, pblk["ln1"])
    new_mamba = None
    if cfg.family == "mla":
        pa = pblk["attn"]
        if decode:
            ckv_new, krope_new = mla.compress_kv(pa, h, cfg, positions)
            sc = kv_cache["ckv"].shape[1]
            b, t = h.shape[0], h.shape[1]
            slot = jnp.mod(cache_idx[:, None] + jnp.arange(t)[None], sc)
            rows = jnp.arange(b)[:, None]
            ckv = kv_cache["ckv"].at[rows, slot].set(
                ckv_new.astype(kv_cache["ckv"].dtype))
            krope = kv_cache["krope"].at[rows, slot].set(
                krope_new.astype(kv_cache["krope"].dtype))
            pos_all = kv_cache["pos"].at[rows, slot].set(positions)
            attn_out = mla.mla_attention_absorbed(
                pa, h, cfg, positions, ckv.astype(h.dtype),
                krope.astype(h.dtype), pos_all, pos_all >= 0)
            new_cache = {"ckv": ckv, "krope": krope, "pos": pos_all}
        else:
            ckv, krope = mla.compress_kv(pa, h, cfg, positions)
            # k_valid masks padding keys (position -1, from a masked
            # bucketed prefill): their k_pos would satisfy every causal
            # comparison otherwise.  All-true for unpadded prompts — the
            # composed mask is then bit-identical to the causal-only one.
            attn_out = mla.mla_attention_full(pa, h, cfg, positions, ckv,
                                              krope, positions,
                                              k_valid=positions >= 0)
            new_cache = None
            if kv_cache is not None:       # prefill: persist compressed kv
                sc = kv_cache["ckv"].shape[1]
                b, t = h.shape[0], h.shape[1]
                slot = jnp.mod(cache_idx[:, None] + jnp.arange(t)[None], sc)
                rows = jnp.arange(b)[:, None]
                new_cache = {
                    "ckv": kv_cache["ckv"].at[rows, slot].set(
                        ckv.astype(kv_cache["ckv"].dtype)),
                    "krope": kv_cache["krope"].at[rows, slot].set(
                        krope.astype(kv_cache["krope"].dtype)),
                    "pos": kv_cache["pos"].at[rows, slot].set(positions),
                }
    else:
        attn_out, new_cache = _dense_attn_block(
            pblk["attn"], h, positions, cfg, window, kv_cache, cache_idx,
            positions3)

    if cfg.family == "hybrid":
        hs = layers.rms_norm(x, pblk["ln_ssm"])
        dt_rank = max(cfg.d_model // 16, 1)
        ssm_out, new_mamba = ssm.mamba_forward(
            pblk["mamba"], hs, mamba_state, dt_rank=dt_rank,
            n_state=cfg.ssm_state)
        attn_out = 0.5 * (attn_out + ssm_out)       # parallel heads (Hymba)

    x = x + attn_out
    new_xattn = None
    if enc_out is not None:
        hx = layers.rms_norm(x, pblk["ln_x"])
        px = pblk["xattn"]
        q = jnp.einsum("btd,dhk->bthk", hx, px["wq"])
        if decode and xattn_cache is not None:
            # cross k/v were computed once at prefill and are re-used.
            k, v = xattn_cache["k"].astype(q.dtype), \
                xattn_cache["v"].astype(q.dtype)
            new_xattn = xattn_cache
        else:
            k = jnp.einsum("btd,dhk->bthk", enc_out, px["wk"])
            v = jnp.einsum("btd,dhk->bthk", enc_out, px["wv"])
            if xattn_cache is not None:     # prefill: persist for decode
                new_xattn = {"k": k.astype(xattn_cache["k"].dtype),
                             "v": v.astype(xattn_cache["v"].dtype)}
            else:
                new_xattn = None
        attn = layers.attention(q, k, v, positions, enc_positions,
                                causal=False)
        x = x + layers.attn_out(attn, px["wo"])

    h2 = layers.rms_norm(x, pblk["ln2"])
    x = x + _ffn(pblk, h2, cfg, decode)
    # "seq" resolves to None by default; binding it to "model" in the rules
    # enables Megatron-style sequence parallelism (reduce-scattered residual
    # stream between blocks) — evaluated in §Perf.
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, new_mamba, new_xattn


def _encoder_block(pblk, x, positions, cfg: ModelConfig):
    h = layers.rms_norm(x, pblk["ln1"])
    q, k, v = layers.gqa_project(h, pblk["attn"]["wq"], pblk["attn"]["wk"],
                                 pblk["attn"]["wv"])
    if cfg.rope_style == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    attn = layers.attention(q, k, v, positions, positions, causal=False)
    x = x + layers.attn_out(attn, pblk["attn"]["wo"])
    h2 = layers.rms_norm(x, pblk["ln2"])
    x = x + _ffn(pblk, h2, cfg, decode=False)
    return x


# =========================================================================
# Stacks (scan over layers)
# =========================================================================

def _scan_blocks(params_blocks, x, positions, cfg: ModelConfig, windows,
                 caches=None, cache_idx=None, positions3=None,
                 mamba_states=None, enc_out=None, xattn_caches=None,
                 enc_positions=None, decode=False, remat=True):
    """lax.scan over the stacked decoder blocks."""

    def body(carry, scanned):
        xc = carry
        pblk, window, kv_c, mb_s, xa_c = scanned
        out, new_kv, new_mb, new_xa = _decoder_block(
            pblk, xc, positions, cfg, window, kv_c, cache_idx, positions3,
            mb_s, enc_out, xa_c, enc_positions, decode)
        return out, (new_kv, new_mb, new_xa)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    windows = jnp.asarray(windows)
    xs = (params_blocks, windows, caches, mamba_states, xattn_caches)
    x, (new_caches, new_mamba, new_xattn) = jax.lax.scan(body, x, xs)
    return x, new_caches, new_mamba, new_xattn


def _scan_xlstm(params, x, cfg: ModelConfig, states=None, decode=False):
    """xLSTM: outer scan over groups; each group = scan over mLSTM layers
    then one sLSTM layer."""
    has_s = cfg.slstm_every > 0

    def m_body(carry, scanned):
        xc = carry
        pm, ln, st = scanned
        h = layers.rms_norm(xc, ln)
        out, new_st = xlstm.mlstm_forward(pm, h, st, decode=decode,
                                          chunk=cfg.mlstm_chunk)
        return xc + out, new_st

    def g_body(carry, scanned):
        xc = carry
        grp = scanned
        new_states = {}
        # remat at GROUP granularity (not per layer): saves 6 residual
        # streams instead of 42 — §Perf iteration (xlstm), ~7× fewer
        # activation saves for one extra in-group forward on backward.
        xc, new_states["m"] = jax.lax.scan(
            m_body, xc, (grp["p_m"], grp["ln_m"], grp["st_m"]))
        if has_s:
            h = layers.rms_norm(xc, grp["ln_s"])
            if decode:
                st = grp["st_s"]
                new_s = xlstm.slstm_step(grp["p_s"], h[:, 0], st,
                                         cfg.n_heads)
                out = jnp.einsum("bd,de->be", new_s[0].astype(xc.dtype),
                                 grp["p_s"]["w_out"])[:, None]
            else:
                out, new_s = xlstm.slstm_forward(grp["p_s"], h,
                                                 grp.get("st_s"),
                                                 cfg.n_heads)
            xc = xc + out
            new_states["s"] = new_s
        return xc, new_states

    if not decode:
        g_body = jax.checkpoint(
            g_body, policy=jax.checkpoint_policies.nothing_saveable)

    grp_xs = {"p_m": params["mlstm"]["blk"], "ln_m": params["mlstm"]["ln"],
              "st_m": states["m"] if states else None}
    if has_s:
        grp_xs["p_s"] = params["slstm"]["blk"]
        grp_xs["ln_s"] = params["slstm"]["ln"]
        grp_xs["st_s"] = states["s"] if states else None
    if states is None:
        per = cfg.slstm_every if has_s else cfg.n_layers
        groups = cfg.n_layers // per
        m_per = per - (1 if has_s else 0)
        B = x.shape[0]
        grp_xs["st_m"] = _init_mlstm_states(cfg, B, groups, m_per)
        if has_s:
            grp_xs["st_s"] = _init_slstm_states(cfg, B, groups)
    x, new_states = jax.lax.scan(g_body, x, grp_xs)
    return x, new_states


def _init_mlstm_states(cfg, B, groups, m_per):
    di, H = cfg.d_inner, cfg.n_heads
    dh = di // H
    return (
        jnp.zeros((groups, m_per, B, cfg.ssm_conv - 1, di), cfg.param_dtype),
        (jnp.zeros((groups, m_per, B, H, dh, dh), jnp.float32),
         jnp.zeros((groups, m_per, B, H, dh), jnp.float32),
         jnp.full((groups, m_per, B, H), -1e30, jnp.float32)),
    )


def _init_slstm_states(cfg, B, groups):
    D = cfg.d_model
    z = jnp.zeros((groups, B, D), jnp.float32)
    return (z, z, z, jnp.full((groups, B, D), -1e30, jnp.float32))


# =========================================================================
# Caches
# =========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, dtype=None) -> Dict[str, Any]:
    """Build the decode cache for a family.  max_len may be < context length
    (ring-buffer / sliding-window serving mode)."""
    dt = dtype or cfg.param_dtype
    L, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    cache: Dict[str, Any] = {"idx": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        per = cfg.slstm_every if cfg.slstm_every else cfg.n_layers
        groups = cfg.n_layers // per
        m_per = per - (1 if cfg.slstm_every else 0)
        cache["states"] = {"m": _init_mlstm_states(cfg, batch, groups, m_per)}
        if cfg.slstm_every:
            cache["states"]["s"] = _init_slstm_states(cfg, batch, groups)
        return cache
    if cfg.family == "mla":
        cache["kv"] = {
            "ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dt),
            "krope": jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dt),
            "pos": jnp.full((L, batch, max_len), -1, jnp.int32),
        }
        return cache
    cache["kv"] = {
        "k": jnp.zeros((L, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((L, batch, max_len, kv, hd), dt),
        "pos": jnp.full((L, batch, max_len), -1, jnp.int32),
    }
    if cfg.family == "hybrid":
        di, n = cfg.d_inner, cfg.ssm_state
        cache["mamba"] = (
            jnp.zeros((L, batch, cfg.ssm_conv - 1, di), dt),
            jnp.zeros((L, batch, di, n), jnp.float32),
        )
    if cfg.is_encdec:
        cache["xattn"] = {
            "k": jnp.zeros((L, batch, enc_len, kv, hd), dt),
            "v": jnp.zeros((L, batch, enc_len, kv, hd), dt),
        }
        cache["enc_positions"] = jnp.zeros((batch, enc_len), jnp.int32)
    return cache


# =========================================================================
# Entry points
# =========================================================================

def _embed_inputs(params, cfg: ModelConfig, tokens, pixel_embeds=None):
    x = layers.embed(tokens, params["embed"], scale=cfg.embed_scale)
    if pixel_embeds is not None:
        # VLM stub frontend: precomputed patch embeddings occupy the first
        # S_vis positions (assignment: frontend is a stub).
        sv = pixel_embeds.shape[1]
        x = jnp.concatenate([pixel_embeds.astype(x.dtype), x[:, sv:]], axis=1)
    return constrain(x, ("batch", None, "embed"))


def _logits(params, cfg: ModelConfig, x):
    x = layers.rms_norm(x, params["final_norm"])
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, table, cfg.tie_embeddings)
    return constrain(logits, ("batch", None, "vocab"))


def encode(params, cfg: ModelConfig, enc_frames, enc_positions):
    """Encoder stack over stub frame embeddings (B, S_enc, D)."""
    x = constrain(enc_frames.astype(cfg.param_dtype), ("batch", None, "embed"))

    def body(carry, pblk):
        return _encoder_block(pblk, carry, enc_positions, cfg), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
    return layers.rms_norm(x, params["enc_norm"])


def forward_train(params, cfg: ModelConfig, batch: Dict[str, Array]):
    """Full-sequence causal logits (B, S, V) f32."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _embed_inputs(params, cfg, tokens, batch.get("pixel_embeds"))
    enc_out = enc_pos = None
    if cfg.is_encdec:
        enc_pos = batch.get("enc_positions")
        if enc_pos is None:
            se = batch["enc_frames"].shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32),
                                       (B, se))
        enc_out = encode(params, cfg, batch["enc_frames"], enc_pos)
    if cfg.family == "ssm":
        x, _ = _scan_xlstm(params, x, cfg)
    else:
        windows = layer_windows(cfg)
        x, _, _, _ = _scan_blocks(
            params["blocks"], x, positions, cfg, windows,
            positions3=batch.get("positions3"), enc_out=enc_out,
            enc_positions=enc_pos)
    return _logits(params, cfg, x)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array]):
    logits = forward_train(params, cfg, batch)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def prefill(params, cfg: ModelConfig, batch: Dict[str, Array],
            cache: Dict[str, Any]):
    """Run the prompt through the model, filling ``cache``.

    Returns (last-token logits (B, V), cache).

    batch["lengths"] ((B,) int32, optional) enables MASKED prefill over
    end-padded prompts: padding columns get position -1 (never written as
    valid keys — attention masks ``pos >= 0``), the cache write pointer
    advances by each row's true length (decode overwrites the padding
    slots), and the returned logits are each row's true-last-token logits.
    This is what lets a serving engine bucket prompt lengths to powers of
    two and compile O(log max_len) prefill kernels instead of one per
    distinct length.  Not supported for recurrent families ("ssm",
    "hybrid"): their per-token state updates cannot be position-masked —
    a padded token would pollute the carried state."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    lengths = batch.get("lengths")
    if lengths is not None:
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"masked (bucketed) prefill is not supported for the "
                f"{cfg.family!r} family: recurrent state carries every "
                "token, padding included — prefill exact lengths instead")
        if positions is None:
            ar = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            positions = jnp.where(ar < lengths[:, None], ar, -1)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _embed_inputs(params, cfg, tokens, batch.get("pixel_embeds"))
    enc_out = enc_pos = None
    if cfg.is_encdec:
        enc_pos = batch.get("enc_positions")
        if enc_pos is None:
            se = batch["enc_frames"].shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32),
                                       (B, se))
        enc_out = encode(params, cfg, batch["enc_frames"], enc_pos)
        cache["enc_positions"] = enc_pos
    if cfg.family == "ssm":
        x, states = _scan_xlstm(params, x, cfg)
        cache["states"] = states
    else:
        windows = layer_windows(cfg)
        x, new_kv, new_mamba, new_xattn = _scan_blocks(
            params["blocks"], x, positions, cfg, windows,
            caches=cache.get("kv"), cache_idx=cache["idx"],
            positions3=batch.get("positions3"),
            mamba_states=cache.get("mamba"), enc_out=enc_out,
            xattn_caches=cache.get("xattn"), enc_positions=enc_pos)
        if new_kv is not None:
            cache["kv"] = new_kv
        if new_mamba is not None:
            cache["mamba"] = new_mamba
        if new_xattn is not None:
            cache["xattn"] = new_xattn
    if lengths is None:
        cache["idx"] = cache["idx"] + S
        x_last = x[:, -1:]
    else:
        cache["idx"] = cache["idx"] + lengths.astype(cache["idx"].dtype)
        idx_last = jnp.clip(lengths - 1, 0, S - 1)
        x_last = x[jnp.arange(B), idx_last][:, None, :]
    logits = _logits(params, cfg, x_last)
    return logits[:, 0], cache


def decode_step(params, cfg: ModelConfig, token: Array,
                cache: Dict[str, Any],
                positions3: Optional[Array] = None):
    """One decode step.  token: (B, 1) → (logits (B, V), cache)."""
    B = token.shape[0]
    pos = cache["idx"][:, None].astype(jnp.int32)
    x = _embed_inputs(params, cfg, token)
    if cfg.family == "ssm":
        x, states = _scan_xlstm(params, x, cfg, states=cache["states"],
                                decode=True)
        cache["states"] = states
    else:
        windows = layer_windows(
            cfg, override_window=cfg.window if (
                cfg.attn_kind == "swa"
                and cache["kv"][next(iter(
                    k for k in ("k", "ckv") if k in cache["kv"]))].shape[2]
                <= cfg.window) else 0)
        enc_pos = cache.get("enc_positions")
        x, new_kv, new_mamba, _ = _scan_blocks(
            params["blocks"], x, pos, cfg, windows,
            caches=cache["kv"], cache_idx=cache["idx"],
            positions3=positions3,
            mamba_states=cache.get("mamba"),
            enc_out=(jnp.zeros((B, 1, cfg.d_model), x.dtype)
                     if cfg.is_encdec else None),
            xattn_caches=cache.get("xattn"), enc_positions=enc_pos,
            decode=True)
        cache["kv"] = new_kv
        if new_mamba is not None:
            cache["mamba"] = new_mamba
    cache["idx"] = cache["idx"] + 1
    logits = _logits(params, cfg, x)
    return logits[:, 0], cache
