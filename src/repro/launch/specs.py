"""Sharding specs for batches, caches and step functions (dry-run + train).

Parameters get their specs from ``transformer.param_pspecs`` (logical axes).
Batch/cache trees are sharded here by path-name rules:

  batch tokens/targets (B, S)        → (dp, None)
  positions3 (3, B, S)               → (None, dp, None)
  pixel/frame embeds (B, S', D)      → (dp, None, None)
  kv caches (L, B, S, KV, hd)        → (None, dp, None, model?, None)
  MLA latent (L, B, S, r)            → (None, dp, None, None)
  mamba conv/state (L, B, ..., di,·) → (None, dp, ..., model?)
  mLSTM states (G, M, B, H, ...)     → (None, None, dp, ...)
  idx (B,)                           → (dp,)

where dp = ("pod", "data") and `model?` applies only when divisible
(the GQA kv<tp replication fallback).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _dp(mesh: Mesh, dim: int = 0) -> Tuple[str, ...]:
    """Data-parallel axes; if ``dim`` is given, only as many axes as the
    dim size divides (batch=1 long-context decode ⇒ fully replicated)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if dim <= 0:
        return axes
    while axes:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size == 0:
            return axes
        axes = axes[1:]
    return ()


def _maybe(mesh: Mesh, axis: str, dim: int):
    if axis in mesh.shape and dim % mesh.shape[axis] == 0:
        return axis
    return None


def batch_pspecs(cfg: ModelConfig, batch: Dict[str, Any], mesh: Mesh
                 ) -> Dict[str, P]:
    out = {}
    for k, v in batch.items():
        if k == "positions3":
            dp = _dp(mesh, v.shape[1])
            out[k] = P(None, dp, None)
        elif v.ndim >= 2:
            dp = _dp(mesh, v.shape[0])
            out[k] = P(dp, *([None] * (v.ndim - 1)))
        else:
            out[k] = P(_dp(mesh, v.shape[0]))
    return out


def cache_pspecs(cfg: ModelConfig, cache: Any, mesh: Mesh) -> Any:

    def spec_for(path: str, leaf) -> P:
        nd = leaf.ndim
        last = path.split("/")[-1]
        if last == "idx":
            return P(_dp(mesh, leaf.shape[0]))
        if path.startswith("states/m"):      # mLSTM (G, M, B, ...)
            dp = _dp(mesh, leaf.shape[2])
            return P(None, None, dp, *([None] * (nd - 3)))
        if path.startswith("states/s"):      # sLSTM (G, B, D)
            dp = _dp(mesh, leaf.shape[1])
            return P(None, dp, *([None] * (nd - 2)))
        if last in ("k", "v"):               # (L, B, S, KV, hd)
            if nd == 5:
                kv_ax = _maybe(mesh, "model", leaf.shape[3])
                # GQA kv < tp: shard the SEQUENCE axis over `model` instead
                # of replicating the cache (decode attention over an
                # S-sharded cache costs one tiny logits all-gather; a
                # replicated 32k cache costs HBM we don't have).
                seq_ax = None if kv_ax else _maybe(mesh, "model",
                                                   leaf.shape[2])
                return P(None, _dp(mesh, leaf.shape[1]), seq_ax, kv_ax,
                         None)
            return P(*([None] * nd))
        if last in ("ckv", "krope"):         # (L, B, S, r) — MLA latent
            return P(None, _dp(mesh, leaf.shape[1]),
                     _maybe(mesh, "model", leaf.shape[2]), None)
        if last == "pos":                    # (L, B, S) — match k/v S axis
            kv_sharded = cfg.n_kv_heads % max(
                mesh.shape.get("model", 1), 1) == 0 and cfg.family != "mla"
            seq_ax = None if kv_sharded else _maybe(mesh, "model",
                                                    leaf.shape[2])
            return P(None, _dp(mesh, leaf.shape[1]), seq_ax)
        if last == "enc_positions":          # (B, S_enc)
            return P(_dp(mesh, leaf.shape[0]), None)
        if path.startswith("mamba"):
            dp = _dp(mesh, leaf.shape[1])
            if path.endswith("/0"):          # conv state (L, B, K-1, di)
                return P(None, dp, None,
                         _maybe(mesh, "model", leaf.shape[3]))
            if path.endswith("/1"):          # ssm state (L, B, di, N)
                return P(None, dp,
                         _maybe(mesh, "model", leaf.shape[2]), None)
            return P(*([None] * nd))
        if nd >= 2:
            return P(None, _dp(mesh, leaf.shape[1]), *([None] * (nd - 2)))
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        specs.append(spec_for(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
