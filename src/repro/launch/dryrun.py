import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and record memory / cost / collective analyses.

THE two lines above must execute before any other import — jax locks the
device count at first initialisation.  Do not move them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
  PYTHONPATH=src python -m repro.launch.dryrun --figmn   # paper-core cell

Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
(read by benchmarks/roofline.py for §Roofline of EXPERIMENTS.md).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat, configs
from repro.configs.shapes import (SHAPES, ShapeSpec, cache_max_len,
                                  cell_applicable, input_specs)
from repro.distributed import hlo_analysis
from repro.distributed.sharding import mesh_rules
from repro.launch import specs as specmod
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import optimizer as optim
from repro.train import trainer

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")


def _mem_dict(ma) -> Dict[str, float]:
    if ma is None:
        return {}
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    return {f: float(getattr(ma, f, 0) or 0) for f in fields}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg: ModelConfig = None) -> Dict[str, Any]:
    """Lower + compile one cell; returns the analysis record."""
    cfg = cfg or configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(np.prod(mesh.devices.shape)),
    }
    t0 = time.time()
    with mesh_rules(mesh):
        pspecs = transformer.param_pspecs(cfg)
        params_abs = transformer.abstract_params(cfg)
        param_sh = specmod.to_named(pspecs, mesh)
        sp = input_specs(cfg, shape)

        if shape.kind == "train":
            tcfg = trainer.TrainConfig()
            step = trainer.make_train_step(cfg, tcfg)
            opt_abs = jax.eval_shape(optim.init, params_abs)
            opt_sh = optim.AdamWState(
                step=NamedSharding(mesh, P()), m=param_sh, v=param_sh)
            bspec = specmod.to_named(
                specmod.batch_pspecs(cfg, sp["batch"], mesh), mesh)

            def fn(params, opt, batch):
                with mesh_rules(mesh):
                    return step(params, opt, batch)

            lowered = jax.jit(
                fn, in_shardings=(param_sh, opt_sh, bspec),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, sp["batch"])
        elif shape.kind == "prefill":
            cache_abs = sp["cache"]
            cache_sh = specmod.to_named(
                specmod.cache_pspecs(cfg, cache_abs, mesh), mesh)
            bspec = specmod.to_named(
                specmod.batch_pspecs(cfg, sp["batch"], mesh), mesh)

            def fn(params, batch, cache):
                with mesh_rules(mesh):
                    return transformer.prefill(params, cfg, batch, cache)

            lowered = jax.jit(
                fn, in_shardings=(param_sh, bspec, cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            ).lower(params_abs, sp["batch"], cache_abs)
        else:                                        # decode / serve_step
            cache_abs = sp["cache"]
            cache_sh = specmod.to_named(
                specmod.cache_pspecs(cfg, cache_abs, mesh), mesh)
            dp = specmod._dp(mesh, shape.global_batch)
            tok_sh = NamedSharding(mesh, P(dp, None))

            if "positions3" in sp:
                def fn(params, token, cache, positions3):
                    with mesh_rules(mesh):
                        return transformer.decode_step(
                            params, cfg, token, cache, positions3=positions3)

                p3_sh = NamedSharding(mesh, P(None, dp, None))
                lowered = jax.jit(
                    fn, in_shardings=(param_sh, tok_sh, cache_sh, p3_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                ).lower(params_abs, sp["token"], cache_abs,
                        sp["positions3"])
            else:
                def fn(params, token, cache):
                    with mesh_rules(mesh):
                        return transformer.decode_step(params, cfg, token,
                                                       cache)

                lowered = jax.jit(
                    fn, in_shardings=(param_sh, tok_sh, cache_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                ).lower(params_abs, sp["token"], cache_abs)

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)
        record["memory"] = _mem_dict(compiled.memory_analysis())
        ca = compat.cost_analysis(compiled)
        record["xla_cost"] = {k: float(v) for k, v in ca.items()
                              if k in ("flops", "bytes accessed")}
        txt = compiled.as_text()
        record["hlo"] = hlo_analysis.analyze(txt)
        record["n_params"] = int(sum(
            np.prod(l.shape) for l in jax.tree.leaves(params_abs)))
        record["n_active_params"] = cfg.n_active_params()
        record["seq_len"] = shape.seq_len
        record["global_batch"] = shape.global_batch
        record["kind"] = shape.kind
    return record


def lower_figmn(multi_pod: bool, dim: int = 256, kmax: int = 512
                ) -> Dict[str, Any]:
    """The paper-core cell: component-sharded FIGMN fit step on the mesh."""
    from repro.core import figmn, sharded
    from repro.core.types import FIGMNConfig, FIGMNState

    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {"arch": "figmn-core", "shape": f"d{dim}_k{kmax}",
              "mesh": "x".join(str(s) for s in mesh.devices.shape),
              "n_devices": int(np.prod(mesh.devices.shape))}
    cfg = FIGMNConfig(kmax=kmax, dim=dim, beta=0.1, delta=1.0,
                      sigma_ini=np.ones((dim,), np.float32))
    state_abs = jax.eval_shape(lambda: figmn.init_state(cfg))
    spec = sharded.state_pspec("model")
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                            is_leaf=lambda x: isinstance(x, P))
    n_stream = 1024
    xs = jax.ShapeDtypeStruct((n_stream, dim), jnp.float32)

    def fit(state, xs):
        return sharded.fit_sharded(cfg, state, xs, mesh, "model")

    t0 = time.time()
    lowered = jax.jit(fit, in_shardings=(state_sh, NamedSharding(mesh, P())),
                      out_shardings=state_sh,
                      donate_argnums=(0,)).lower(state_abs, xs)
    record["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 1)
    record["memory"] = _mem_dict(compiled.memory_analysis())
    record["hlo"] = hlo_analysis.analyze(compiled.as_text())
    record["kind"] = "figmn_fit"
    record["seq_len"] = n_stream
    record["global_batch"] = 1
    record["n_params"] = kmax * dim * dim
    record["n_active_params"] = kmax * dim * dim
    # the paper cost model fields benchmarks/roofline.py derives
    # model-FLOPs from (K over the mesh's "model" axis — the actual
    # sharding divisor, not an axis-count guess)
    record["k"] = kmax
    record["d"] = dim
    record["c"] = 0
    record["points"] = n_stream
    record["model_axis"] = int(
        dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1))
    return record


def save_record(rec: Dict[str, Any], out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec.get('mesh', 'skip')}.json"
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--figmn", action="store_true")
    ap.add_argument("--multi-pod", choices=("no", "yes", "both"),
                    default="no")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    pods = {"no": (False,), "yes": (True,), "both": (False, True)}[
        args.multi_pod]
    jobs = []
    if args.figmn:
        for mp in pods:
            jobs.append(("figmn", None, mp))
    elif args.all:
        for arch in configs.ARCH_IDS:
            for shape in SHAPES:
                for mp in pods:
                    jobs.append((arch, shape, mp))
    else:
        for mp in pods:
            jobs.append((args.arch, args.shape, mp))

    failures = 0
    for arch, shape, mp in jobs:
        tag = f"{arch}/{shape or '-'}/{'2pod' if mp else '1pod'}"
        try:
            rec = lower_figmn(mp) if arch == "figmn" \
                else lower_cell(arch, shape, mp)
            path = save_record(rec, args.out)
            if "skipped" in rec:
                print(f"[SKIP] {tag}: {rec['skipped']}")
            else:
                mem = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
                print(f"[OK]   {tag}: compile={rec['compile_s']}s "
                      f"args/dev={mem:.2f}GiB "
                      f"flops/dev={rec['hlo']['flops']:.3g} "
                      f"coll/dev={rec['hlo']['coll_bytes_total']:.3g}B "
                      f"→ {os.path.basename(path)}")
        except Exception as e:
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
