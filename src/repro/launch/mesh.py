"""Production mesh construction.

Target hardware: TPU v5e pods of 256 chips (16×16 ICI torus); multi-pod
adds a leading `pod` axis over the slower inter-pod links.  Constructed as
a FUNCTION so importing this module never touches jax device state (the
dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests / small runs (e.g. (1, 1) on CPU)."""
    return compat.make_mesh(shape, axes)


def host_device_mesh(model_parallel: int = 1) -> Mesh:
    """Whatever this host has, as (data, model)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel),
                     ("data", "model"))
