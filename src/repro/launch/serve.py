"""Serving driver: continuous-batching engine on the host's devices.

Loads (or random-inits) a model, spins the ServeEngine over a synthetic
request stream, reports throughput/latency percentiles, and runs the FIGMN
OOD monitor over prompt embeddings (the paper's algorithm on the serving
path) as a ``repro.fleet.FleetCoordinator``: request features are hash-
sharded across N StreamRuntime replicas (chunked ingestion, lifecycle
budget, drift detection per shard), periodically consolidated into one
global mixture, and OOD scores are served from that read-only snapshot so
the serving path never blocks on ingestion.  At production scale the same
fleet runs with one replica per serving pod.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.api import Mixture, MixtureSpec
from repro.checkpoint import CheckpointManager
from repro.core import figmn
from repro.core.types import FIGMNConfig
from repro.fleet import AutoscaleConfig, FleetConfig
from repro.ft import RetryPolicy, SupervisorConfig
from repro.models import transformer as tr
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.rpc import RpcConfig
from repro.serve.engine import Request, ServeEngine
from repro.stream import DriftConfig, LifecycleConfig, RuntimeConfig, costmodel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a training checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ood-replicas", type=int, default=2,
                    help="stream-fleet replicas for the OOD monitor "
                         "(with --ood-autoscale: the maximum)")
    ap.add_argument("--ood-autoscale", action="store_true",
                    help="let the OOD fleet autoscale from 1 replica up "
                         "to --ood-replicas off its own telemetry "
                         "(load skew / budget pressure / drift rate)")
    ap.add_argument("--ood-workers", type=int, default=0, metavar="N",
                    help="run the OOD fleet's replicas as N WORKER "
                         "PROCESSES over repro.rpc instead of threads "
                         "(0 = threads; overrides --ood-replicas). Each "
                         "worker hosts one StreamRuntime; shards ingest "
                         "in parallel, the supervisor's recovery ladder "
                         "gains the worker_dead failure class, and the "
                         "autoscaler allocates/releases processes at "
                         "consolidation boundaries")
    ap.add_argument("--ood-transport", choices=("tcp", "unix"),
                    default="tcp",
                    help="worker RPC transport (with --ood-workers): "
                         "tcp = 127.0.0.1 loopback, unix = socket file")
    ap.add_argument("--ood-supervise", action="store_true",
                    help="run the OOD fleet under the FleetSupervisor "
                         "(repro.ft): heartbeat watchdog per replica, "
                         "chunk retry with backoff+jitter, and the "
                         "quarantine → re-route → checkpoint-restore "
                         "recovery ladder with exact mass accounting")
    ap.add_argument("--ood-heartbeat-timeout", type=float, default=30.0,
                    metavar="SECONDS",
                    help="supervisor watchdog: quarantine a replica whose "
                         "chunk boundary goes silent this long (must "
                         "clear the first-chunk compile; only with "
                         "--ood-supervise)")
    ap.add_argument("--ood-max-staleness", type=float, default=None,
                    metavar="SECONDS",
                    help="degraded-serving bound: OOD reads fail with "
                         "StalenessExceeded rather than serve a snapshot "
                         "older than this (default: serve any last-good "
                         "snapshot)")
    ap.add_argument("--score-shortlist", type=int, default=0,
                    metavar="C",
                    help="top-C component shortlist for the OOD monitor "
                         "(0 = dense): both the ingest hot path and the "
                         "serving score() drop from O(K·D²) to "
                         "O(K·D + C·D²) per point, exact when C >= K")
    ap.add_argument("--cost-table", default=None, metavar="PATH",
                    help="device-calibrated dispatch cost table "
                         "(benchmarks.figmn_dispatch / "
                         "stream.costmodel.calibrate): the OOD monitor's "
                         "ingest and eq. 27 predict paths route by "
                         "measured cost instead of the static heuristic")
    ap.add_argument("--explain-dispatch", action="store_true",
                    help="print the dispatch decision report for the OOD "
                         "monitor config (chosen path, heuristic "
                         "counterfactual, backing calibration cell, "
                         "roofline bottleneck) and how each candidate "
                         "ranked")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve Prometheus text exposition of the obs "
                         "registry on http://0.0.0.0:PORT/metrics "
                         "(0 = ephemeral port; printed at startup)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable structured spans and write them to PATH "
                         "on exit (.json => Chrome trace_event for "
                         "chrome://tracing / Perfetto; else JSONL)")
    args = ap.parse_args()

    if args.metrics_port is not None:
        server = obs_export.serve_metrics(args.metrics_port)
        print(f"obs: serving /metrics on port "
              f"{server.server_address[1]}")
    if args.trace:
        obs_trace.enable()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        step = mgr.latest_step()
        if step is not None:
            print(f"restoring params from step {step}")
            params = mgr.restore(step, {"params": params})["params"]

    engine = ServeEngine(cfg, params, n_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t_submit = {}
    reqs = []
    for i in range(args.requests):
        p = rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(4, 24))).astype(np.int32)
        r = Request(rid=i, prompt=p, max_tokens=args.max_new)
        engine.submit(r)
        t_submit[i] = time.perf_counter()
        reqs.append(r)

    t0 = time.perf_counter()
    lat = {}
    while engine.queue or any(s is not None for s in engine.slot_req):
        engine.tick()
        now = time.perf_counter()
        for r in reqs:
            if r.done and r.rid not in lat:
                lat[r.rid] = now - t_submit[r.rid]
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    ls = sorted(lat.values())
    print(f"served {len(reqs)} reqs / {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s)")
    print(f"latency p50={ls[len(ls) // 2] * 1e3:.0f}ms "
          f"p95={ls[int(len(ls) * 0.95) - 1] * 1e3:.0f}ms")

    # FIGMN OOD monitor over prompt-embedding means (first 16 dims), run
    # through the unified estimator API as the mixture session a serving
    # deployment keeps open: the spec resolves to a hash-sharded fleet
    # (or a telemetry-autoscaled one), request features stream through
    # chunked per-replica ingest with lifecycle budgets and drift
    # detection, and every read — density scores AND eq. 27 conditional
    # reconstructions — is served from the read-only consolidated
    # snapshot without ever blocking ingestion.
    emb = np.asarray(params["embed"], np.float32)
    feats = np.stack([emb[r.prompt].mean(0)[:16] for r in reqs])
    gcfg = FIGMNConfig(kmax=8, dim=16, beta=0.1, delta=1.0, vmin=1e9,
                       spmin=0.0, update_mode="exact",
                       # C > 0 flips ALL hot paths sublinear: ingest
                       # dispatches to the "sparse" body and the serving
                       # frontend shortlists both score() and predict()
                       shortlist_c=max(args.score_shortlist, 0),
                       sigma_ini=figmn.sigma_from_data(
                           jnp.asarray(feats), 1.0))
    cost_table = costmodel.CostTable.load(args.cost_table) \
        if args.cost_table else None
    chunk = max(args.requests // 4, 4)
    if args.explain_dispatch:
        print(costmodel.explain(gcfg, chunk=chunk,
                                cost_table=cost_table))
    monitor = Mixture(MixtureSpec(
        model=gcfg,
        tier="autoscaled" if args.ood_autoscale else "fleet",
        cost_table=cost_table,
        runtime=RuntimeConfig(
            chunk=chunk,
            lifecycle=LifecycleConfig(k_budget=8, every=4),
            drift=DriftConfig(window=8, threshold=8.0,
                              response="inflate")),
        fleet=FleetConfig(
            n_replicas=(args.ood_workers if args.ood_workers > 0
                        else (1 if args.ood_autoscale
                              else args.ood_replicas)),
            placement="process" if args.ood_workers > 0 else "thread",
            rpc=(RpcConfig(transport=args.ood_transport)
                 if args.ood_workers > 0 else None),
            router="hash", consolidate_every=1, global_kmax=8,
            autoscale=AutoscaleConfig(
                min_replicas=1,
                max_replicas=max(args.ood_workers, args.ood_replicas, 1),
                cooldown=1) if args.ood_autoscale else None,
            supervisor=SupervisorConfig(
                heartbeat_timeout_s=args.ood_heartbeat_timeout,
                retry=RetryPolicy(seed=args.seed))
            if args.ood_supervise else None,
            max_staleness_s=args.ood_max_staleness)))
    if args.metrics_port is not None and args.ood_workers > 0:
        # one aggregated /metrics: the coordinator's registry merged with
        # every worker process's scraped registry (mergeable histograms)
        server.RequestHandlerClass.extra_sources = tuple(
            monitor.engine.worker_metric_sources())
    monitor.partial_fit(feats)
    summary = monitor.summary()
    # snapshot reads — non-blocking w.r.t. ingestion (score_async /
    # predict_async exist for callers that also want off their own thread)
    scores = monitor.score_samples(feats)
    # eq. 27 on the serving path: reconstruct the last embedding feature
    # from the rest — the residual is a per-request drift/corruption probe.
    # return_var adds the conditional variance off the same cached factor
    # bundle (one extra Schur term), turning the raw residual into a
    # CALIBRATED z-score: |x̂−x|/σ ≫ 1 flags a corrupted request even when
    # the absolute residual is small in a tight regime.
    recon, rvar = monitor.predict(feats[:, :-1], targets=[gcfg.dim - 1],
                                  return_var=True)
    resid = float(jnp.mean(jnp.abs(recon[:, 0] - feats[:, -1])))
    zscore = float(jnp.mean(jnp.abs(recon[:, 0] - feats[:, -1])
                            / jnp.sqrt(jnp.maximum(rvar[:, 0], 1e-12))))
    monitor.close()
    shortcut = (f"shortlist C={gcfg.shortlist_c}, "
                if gcfg.shortlist_c > 0 else "")
    if args.ood_supervise:
        shortcut += (f"supervised (quarantined="
                     f"{summary.get('quarantined_replicas', [])}, "
                     f"recoveries={summary.get('recoveries', 0)}, "
                     f"lost={summary.get('supervisor_points_lost', 0)}), ")
    print(f"FIGMN OOD fleet active ({summary['replicas']} replicas, "
          f"{shortcut}router load {summary['router_load']}): "
          f"in-dist logp median "
          f"{float(jnp.median(scores)):.1f} over {len(reqs)} requests "
          f"({summary['points_per_s']:.0f} feats/s, "
          f"global K={summary['global_active_k']}, "
          f"snapshot v{summary['snapshot_version']}, "
          f"drift alarms={summary['drift_alarms']}, "
          f"scale events={summary['scale_ups']}+{summary['scale_downs']} "
          f"epoch={summary['epoch']}, "
          f"eq27 |x̂₁₅−x₁₅| = {resid:.3f}, z = {zscore:.2f})")

    if args.trace:
        tracer = obs_trace.disable()
        if args.trace.endswith(".json"):
            tracer.export_chrome(args.trace)
        else:
            tracer.export_jsonl(args.trace)
        print(f"obs: wrote {len(tracer.spans())} spans to {args.trace}")


if __name__ == "__main__":
    main()
