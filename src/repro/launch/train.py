"""Training driver with fault tolerance.

Runs any assigned architecture (``--arch``, reduced with ``--smoke``) on the
host's devices; wires together: synthetic sharded data pipeline, jitted
pjit train step (FSDP+TP from the logical rules), async checkpointing with
auto-resume, the FIGMN telemetry anomaly detector (the paper's algorithm —
divergence/straggler alarms) and the straggler monitor with elastic-rescale
hooks.

Example (CPU, end-to-end driver deliverable):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.distributed.sharding import mesh_rules
from repro.ft.anomaly import AnomalyDetector
from repro.ft.straggler import StragglerMonitor
from repro.launch.mesh import host_device_mesh
from repro.models import transformer
from repro.train import optimizer as optim
from repro.train import trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    mesh = host_device_mesh(args.model_parallel)
    tcfg = trainer.TrainConfig(
        opt=optim.AdamWConfig(lr_peak=args.lr, warmup_steps=args.steps // 10,
                              total_steps=args.steps),
        microbatches=args.microbatches)

    with mesh_rules(mesh):
        params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = optim.init(params)
    print(f"arch={cfg.name} params={transformer.param_count(params):,} "
          f"mesh={dict(mesh.shape)}")

    ckpt = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name))
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        print(f"auto-resume from step {latest}")
        state = ckpt.restore(latest, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = latest

    pipe = SyntheticTokens(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed))
    step_fn = trainer.jit_train_step(cfg, tcfg, mesh)

    detector = AnomalyDetector(dim=3)
    monitor = StragglerMonitor(hosts=[f"host{i}" for i in
                                      range(max(jax.process_count(), 1))])
    stop = {"now": False}
    signal.signal(signal.SIGTERM,
                  lambda *_: stop.__setitem__("now", True))

    extras = {}
    if cfg.family == "vlm":
        sv = args.seq // 8
        extras["pixel_embeds"] = jnp.zeros((args.batch, sv, cfg.d_model),
                                           cfg.param_dtype)
        extras["positions3"] = jnp.broadcast_to(
            jnp.arange(args.seq, dtype=jnp.int32), (3, args.batch, args.seq))
    if cfg.is_encdec:
        extras["enc_frames"] = jnp.zeros(
            (args.batch, args.seq // 4, cfg.d_model), cfg.param_dtype)

    t_last = time.time()
    for step in range(start_step, args.steps):
        raw = pipe.batch(step)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        batch.update(extras)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        step_time = time.time() - t_last
        t_last = time.time()

        monitor.report("host0", step_time)
        verdict = detector.update({
            "loss": float(metrics["loss"]),
            "grad_norm": float(metrics["grad_norm"]),
            "step_time": step_time,
        })
        if verdict["anomalous"]:
            print(f"[FT] step {step}: telemetry anomaly "
                  f"d2={verdict['d2']:.1f} > {verdict['thresh']:.1f} — "
                  f"checkpointing defensively")
            ckpt.save(step, {"params": params, "opt": opt_state})
        for evicted in monitor.check():
            print(f"[FT] straggler evicted: {evicted} — elastic rescale "
                  f"would restore latest checkpoint on the reduced mesh")

        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {step_time*1e3:.0f}ms")
        if step and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
        if stop["now"]:
            print("[FT] SIGTERM: preemption checkpoint + exit")
            ckpt.save(step, {"params": params, "opt": opt_state})
            break
    ckpt.wait()
    print("done.")


if __name__ == "__main__":
    main()
