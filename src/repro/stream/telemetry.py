"""Per-chunk runtime telemetry, feeding the fleet anomaly detector.

Every ingested chunk produces one ChunkMetrics record: pool occupancy,
create/prune/merge rates, drift score, dispatch path and wall-time.  The
Telemetry sink keeps a bounded history, aggregates a summary (points/sec,
totals), and can forward each record into ``repro.ft.anomaly`` — the
paper's own algorithm watching the runtime that runs the paper's algorithm
(the detector learns the joint density of [latency, active K, NLL] and
flags chunks whose telemetry is jointly novel).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.ft.anomaly import AnomalyDetector
from repro.obs import export as obs_export


@dataclasses.dataclass
class ChunkMetrics:
    idx: int
    n_points: int
    active_k: int
    created: int = 0
    pruned: int = 0
    merged: int = 0
    spawned: int = 0
    # mean_ll / novelty_rate are prequential host-side statistics: NaN when
    # no per-chunk host consumer (drift CUSUM / ft.anomaly) required the
    # device pull — 0.0 would masquerade as "no novelty observed"
    mean_ll: float = float("nan")
    novelty_rate: float = float("nan")
    drift_score: float = 0.0
    drift_alarm: bool = False
    path: str = "scan"
    latency_s: float = 0.0

    @property
    def points_per_s(self) -> float:
        # latency_s == 0 means the timer under-resolved, not that the chunk
        # was infinitely fast — and 0.0 would be indistinguishable from a
        # stalled chunk.  NaN is the honest answer; aggregators are
        # nan-aware (Telemetry.summary, fleet telemetry's rate sum).
        if self.latency_s > 0:
            return self.n_points / self.latency_s
        return float("nan")


class Telemetry:
    """Bounded metric history + aggregate counters + ft.anomaly bridge."""

    #: totals kept as RUNNING counters (exact for unbounded streams);
    #: ``history`` is a bounded window for inspection only.
    _COUNTERS = ("created", "pruned", "merged", "spawned")

    def __init__(self, capacity: int = 1024,
                 anomaly: Optional[AnomalyDetector] = None):
        self.capacity = int(capacity)
        self.history: List[ChunkMetrics] = []
        self.anomaly = anomaly
        self.anomalies: List[int] = []
        self.total_points = 0
        self.total_time_s = 0.0
        self.total_chunks = 0
        self.totals: Dict[str, int] = {k: 0 for k in self._COUNTERS}
        self.total_drift_alarms = 0
        # vmem-path accept counter: accumulated on DEVICE by the runtime
        # and folded in here only at lifecycle boundaries (no per-chunk
        # host sync)
        self.total_accepted = 0
        # rows quarantined by the non-finite guard (never ingested —
        # excluded from total_points, reconciled by the fleet's mass
        # accounting identity)
        self.total_quarantined = 0

    def record(self, m: ChunkMetrics) -> None:
        self.history.append(m)
        if len(self.history) > self.capacity:
            self.history = self.history[-self.capacity:]
        self.total_points += m.n_points
        self.total_time_s += m.latency_s
        self.total_chunks += 1
        for k in self._COUNTERS:
            self.totals[k] += getattr(m, k)
        self.total_drift_alarms += bool(m.drift_alarm)
        if self.anomaly is not None and m.latency_s > 0:
            verdict = self.anomaly.update({
                "chunk_latency": m.latency_s,
                "active_k": float(max(m.active_k, 1)),
                "nll": max(-m.mean_ll, 1e-6)
                if m.mean_ll == m.mean_ll else 1e-6,
            })
            if verdict.get("anomalous"):
                self.anomalies.append(m.idx)

    def add_quarantined(self, n: int) -> None:
        """Count rows the finite guard quarantined (NaN/Inf) — they never
        reach the learner, so they are NOT in total_points; the fleet's
        mass-accounting identity reconciles them explicitly."""
        self.total_quarantined += int(n)

    def add_accepted(self, n: int) -> None:
        """Fold a batch of vmem-path gate accepts into the running total
        (the runtime defers the device pull to lifecycle boundaries)."""
        self.total_accepted += int(n)

    def add_lifecycle(self, pruned: int, merged: int, spawned: int) -> None:
        """Fold an off-chunk lifecycle pass into totals + the last record."""
        self.totals["pruned"] += pruned
        self.totals["merged"] += merged
        self.totals["spawned"] += spawned
        if self.history:
            last = self.history[-1]
            last.pruned += pruned
            last.merged += merged
            last.spawned += spawned

    # -- checkpoint round-trip of the RUNNING counters (the bounded history
    # -- is inspection-only and deliberately not persisted) ----------------

    def export_counters(self):
        # host-side numpy, 64-bit: an unbounded stream overflows int32 in
        # hours at fleet rates, and the manager preserves numpy template
        # leaves exactly (no jax no-x64 downcast)
        out = {"total_points": np.asarray(self.total_points, np.int64),
               "total_time_s": np.asarray(self.total_time_s, np.float64),
               "total_chunks": np.asarray(self.total_chunks, np.int64),
               "total_drift_alarms": np.asarray(self.total_drift_alarms,
                                                np.int64),
               "total_accepted": np.asarray(self.total_accepted, np.int64),
               "total_quarantined": np.asarray(self.total_quarantined,
                                               np.int64)}
        for k in self._COUNTERS:
            out[k] = np.asarray(self.totals[k], np.int64)
        return out

    def load_counters(self, payload) -> None:
        self.total_points = int(payload["total_points"])
        self.total_time_s = float(payload["total_time_s"])
        self.total_chunks = int(payload["total_chunks"])
        self.total_drift_alarms = int(payload["total_drift_alarms"])
        # pre-shortlist checkpoints restore via missing="template" ⇒ zeros
        self.total_accepted = int(payload.get("total_accepted", 0))
        self.total_quarantined = int(payload.get("total_quarantined", 0))
        for k in self._COUNTERS:
            self.totals[k] = int(payload[k])

    @classmethod
    def counters_template(cls):
        out = {"total_points": np.zeros((), np.int64),
               "total_time_s": np.zeros((), np.float64),
               "total_chunks": np.zeros((), np.int64),
               "total_drift_alarms": np.zeros((), np.int64),
               "total_accepted": np.zeros((), np.int64),
               "total_quarantined": np.zeros((), np.int64)}
        for k in cls._COUNTERS:
            out[k] = np.zeros((), np.int64)
        return out

    def summary(self) -> Dict[str, object]:
        last = self.history[-1] if self.history else None
        # nan-aware aggregate: total_time_s sums only measurable latencies,
        # so the running rate stays exact even when individual chunks
        # under-resolved (their NaN points_per_s never pollutes the sum);
        # with NO measurable time at all the rate is unknown — NaN, not 0
        return {
            "chunks": self.total_chunks,
            "total_points": self.total_points,
            "points_per_s": (self.total_points / self.total_time_s
                             if self.total_time_s > 0 else float("nan")),
            "active_k": last.active_k if last else 0,
            **dict(self.totals),
            "accepted": self.total_accepted,
            "quarantined": self.total_quarantined,
            "drift_alarms": self.total_drift_alarms,
            "telemetry_anomalies": list(self.anomalies),
        }

    def to_json(self, path: str) -> None:
        obs_export.to_json(path, {
            "kind": "stream_telemetry",
            "summary": self.summary(),
            "chunks": [dataclasses.asdict(m) for m in self.history]})
