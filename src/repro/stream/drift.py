"""Drift detection for non-stationary streams.

Two signals, both derived from ONE shared frozen-parameter pass per chunk
(ingest.chunk_stats — the gate and the log-density reuse the same d²):

  * the FIGMN novelty gate itself (§2.1): the fraction of a chunk's points
    that fail the chi² gate — a distribution shift shows up first as a
    burst of novelty,
  * a CUSUM over the per-chunk mean log-likelihood: slow covariate drift
    depresses log p(x) long before it triggers the gate.  The one-sided
    CUSUM  g ← max(0, g + (μ_ref − ll − κσ_ref)/σ_ref)  accumulates
    standardised evidence that the stream no longer matches the learned
    density and alarms at g > h (Page 1954; the standard streaming choice —
    cf. Gepperth & Pfülb 2019's discussion of GMM drift adaptation).

Responses (applied by the runtime, severity chosen by config):

  "none"        detect only,
  "inflate"     multiply every covariance by ``inflate`` (Λ /= c,
                log|C| += D·log c): keeps means but widens the gates so the
                learner re-adapts quickly — the cheap response,
  "reset_weak"  deactivate the weakest ``reset_frac`` of live components,
                freeing budget for the new regime while keeping the strong
                survivors,
  "fork"        checkpoint the pre-drift mixture (the runtime saves it
                before responding), then reset_weak — the old regime stays
                recoverable for later replay/serving.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.types import FIGMNConfig, FIGMNState

RESPONSES = ("none", "inflate", "reset_weak", "fork")


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    window: int = 8          # chunks in the rolling reference window
    threshold: float = 8.0   # CUSUM alarm level h (std units)
    slack: float = 0.5       # CUSUM allowance κ (std units)
    min_chunks: int = 4      # warm-up before alarms may fire
    novelty_weight: float = 4.0   # gate-failure-rate contribution to g
    response: str = "reset_weak"
    reset_frac: float = 0.5
    inflate: float = 4.0

    def __post_init__(self):
        if self.response not in RESPONSES:
            raise ValueError(f"response must be one of {RESPONSES}")


class DriftDetector:
    """Windowed log-likelihood CUSUM + novelty-rate drift detector."""

    def __init__(self, cfg: DriftConfig):
        self.cfg = cfg
        self._ref: list = []       # rolling per-chunk mean-ll reference
        self._ref_nov: list = []   # rolling novelty-rate reference
        self._g = 0.0
        self.alarms = 0

    @property
    def score(self) -> float:
        return self._g

    def reset_baseline(self) -> None:
        """Restart the CUSUM and its reference window — called when the
        monitored mixture changes out from under the detector (fleet scale
        events move pool halves between replicas), so the old
        log-likelihood baseline would read as spurious drift."""
        self._g = 0.0
        self._ref = []
        self._ref_nov = []

    def update(self, mean_ll: float, novelty_rate: float,
               weight: float = 1.0) -> Tuple[float, bool]:
        """Feed one chunk's stats; returns (score, alarm).

        weight: fraction of a nominal chunk this record covers — a runt
        tail chunk of B points carries B/chunk worth of evidence (its mean
        ll has √(chunk/B)× the noise), so its increment is scaled down
        rather than letting two noisy points fake a regime change.

        On alarm the CUSUM resets and the reference window restarts from
        the post-drift regime (the learner is about to re-adapt, so the old
        baseline is void either way).
        """
        c = self.cfg
        weight = min(max(weight, 0.0), 1.0)
        # float32-quantise everything that enters persistent state: the
        # checkpoint payload is float32, so this makes save/resume
        # LOSSLESS — a resumed detector continues bit-identically.
        mean_ll = float(np.float32(mean_ll))
        novelty_rate = float(np.float32(novelty_rate))
        if len(self._ref) >= c.min_chunks:
            mu = float(np.mean(self._ref))
            sd = float(np.std(self._ref)) or 1.0
            self._g = max(0.0, self._g
                          + ((mu - mean_ll) / sd - c.slack) * weight)
            # only EXCESS novelty counts: during early learning the gate
            # fires constantly (that's Algorithm 3 working, not drift), so
            # the baseline rate is subtracted before it feeds the score
            base_nov = float(np.mean(self._ref_nov)) if self._ref_nov else 0.0
            self._g += c.novelty_weight * weight \
                * max(0.0, novelty_rate - base_nov)
            self._g = float(np.float32(self._g))
            if self._g > c.threshold:
                self.alarms += 1
                self._g = 0.0
                self._ref = []
                self._ref_nov = []
                return c.threshold, True
        self._ref.append(mean_ll)
        self._ref_nov.append(novelty_rate)
        if len(self._ref) > c.window:
            self._ref = self._ref[-c.window:]
            self._ref_nov = self._ref_nov[-c.window:]
        return self._g, False

    # -- checkpoint round-trip (fixed-shape arrays: the manager's manifest
    # -- keys/shapes must not depend on how full the reference window is) --

    def export_state(self):
        """Detector state as a fixed-shape array dict (NaN-padded window)."""
        w = self.cfg.window
        ref = np.full((w,), np.nan, np.float32)
        nov = np.full((w,), np.nan, np.float32)
        ref[:len(self._ref)] = self._ref
        nov[:len(self._ref_nov)] = self._ref_nov
        return {"ref": jnp.asarray(ref), "ref_nov": jnp.asarray(nov),
                "count": jnp.asarray(len(self._ref), jnp.int32),
                "g": jnp.asarray(self._g, jnp.float32),
                "alarms": jnp.asarray(self.alarms, jnp.int32)}

    def load_state(self, payload) -> None:
        n = int(payload["count"])
        self._ref = [float(v) for v in np.asarray(payload["ref"])[:n]]
        self._ref_nov = [float(v)
                         for v in np.asarray(payload["ref_nov"])[:n]]
        self._g = float(payload["g"])
        self.alarms = int(payload["alarms"])

    @staticmethod
    def state_template(cfg: DriftConfig):
        """Zero-filled payload matching export_state (checkpoint restore)."""
        w = cfg.window
        return {"ref": jnp.zeros((w,), jnp.float32),
                "ref_nov": jnp.zeros((w,), jnp.float32),
                "count": jnp.zeros((), jnp.int32),
                "g": jnp.zeros((), jnp.float32),
                "alarms": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Responses (pure functions on state)
# ---------------------------------------------------------------------------

def inflate_covariances(cfg: FIGMNConfig, state: FIGMNState,
                        factor: float) -> FIGMNState:
    """C ← factor·C for every active slot: Λ /= factor, log|C| += D·log f."""
    f = jnp.asarray(factor, cfg.dtype)
    sel = state.active
    lam = jnp.where(sel[:, None, None], state.lam / f, state.lam)
    logdet = jnp.where(sel, state.logdet + cfg.dim * jnp.log(f),
                       state.logdet)
    return dataclasses.replace(state, lam=lam, logdet=logdet)


def reset_weakest(cfg: FIGMNConfig, state: FIGMNState,
                  frac: float) -> FIGMNState:
    """Deactivate the lowest-sp ``frac`` of live components (≥1, < all)."""
    act = np.asarray(state.active)
    live = int(act.sum())
    n_reset = min(max(int(round(live * frac)), 1), max(live - 1, 0))
    if n_reset == 0:
        return state
    sp = np.where(act, np.asarray(state.sp), np.inf)
    idx = np.argsort(sp)[:n_reset]
    keep = act.copy()
    keep[idx] = False
    return dataclasses.replace(state, active=jnp.asarray(keep))


def respond(cfg: FIGMNConfig, dcfg: DriftConfig, state: FIGMNState
            ) -> FIGMNState:
    """Apply the configured drift response ("fork" checkpointing is the
    runtime's job — here it degrades to reset_weak)."""
    if dcfg.response == "inflate":
        return inflate_covariances(cfg, state, dcfg.inflate)
    if dcfg.response in ("reset_weak", "fork"):
        return reset_weakest(cfg, state, dcfg.reset_frac)
    return state
