"""StreamRuntime — the orchestrator that owns the full online loop.

One object unifies what previously lived in four places (core.figmn one-shot
fits, kernels.figmn_stream segments, ft.anomaly ad-hoc loops, example
scripts): chunked ingestion (ingest.py), pool lifecycle (lifecycle.py),
drift handling (drift.py) and telemetry (telemetry.py), with
checkpoint-backed resume via checkpoint.manager.

Invariant (tested): with lifecycle and drift disabled, ``ingest`` over any
chunking equals ONE ``core.figmn.fit`` pass over the concatenated stream —
chunking only re-slices the lax.scan, it never changes the math.  This is
the contract that lets later scaling PRs (sharded replicas via core.merge,
async serving) swap the per-chunk body without re-validating the learner.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

import jax

from repro.checkpoint import CheckpointManager
from repro.core import figmn, inference, shortlist
from repro.core.types import Array, FIGMNConfig, FIGMNState, chi2_quantile
from repro.obs import registry as obs_registry
from repro.obs.trace import span
from repro.stream import costmodel
from repro.stream import drift as drift_mod
from repro.stream import ingest, lifecycle, telemetry
from repro.ft.anomaly import AnomalyDetector
from repro.ft.retry import RetryPolicy


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Orchestration knobs (the FIGMN hyper-parameters live in FIGMNConfig).

    chunk:            micro-batch size (points per dispatch).
    path:             "auto" | "scan" | "vmem" | "sparse" (see
                      ingest.select_path; "sparse" — the top-C shortlist
                      body — needs cfg.shortlist_c > 0 and is what "auto"
                      picks whenever the config enables a shortlist).
    lifecycle:        pool-management policy; None disables (creation and
                      §2.3 pruning then happen inline in the scan body,
                      matching one-shot figmn.fit exactly).
    drift:            drift policy; None disables detection entirely.
    checkpoint_dir:   enables checkpoint/resume; None disables.
    checkpoint_every: chunks between periodic saves (0 ⇒ only final/fork).
    vmem_budget:      bytes assumed available for the VMEM-resident
                      kernel; None (the default) resolves it from the
                      device's own memory stats where the backend exposes
                      a VMEM capacity, falling back to the 12 MiB
                      constant (costmodel.resolve_vmem_budget).
    device:           explicit backend platform ("cpu"/"gpu"/"tpu") the
                      dispatch decision is for; None keys off the process
                      default backend.  A checkpoint restored on
                      different hardware re-resolves against the new
                      device instead of replaying a stale decision.
    cost_table:       a costmodel.CostTable (or a path to its JSON dump)
                      of measured per-path costs; when present and it has
                      cells for this device key, dispatch picks the
                      measured-fastest path instead of the heuristic.
                      None ⇒ the PR-6 heuristic, bit-compatibly.
    telemetry_anomaly: learn a FIGMN over the runtime's own telemetry
                      (ft.anomaly) and flag anomalous chunks.
    on_nonfinite:     NaN/Inf row policy, applied by ``ingest.finite_guard``
                      before any chunk can touch Λ: "drop" quarantines the
                      bad rows (default — state bit-identical to a stream
                      that never contained them), "reject" quarantines the
                      whole chunk, "raise" raises NonFiniteChunkError.
                      Quarantined rows land in the
                      figmn_points_quarantined_total counter and the
                      telemetry's ``quarantined`` total.
    chunk_retry:      recovery-ladder rung 1 (ft.retry.RetryPolicy): a
                      chunk whose ingest raises is retried with backoff +
                      seeded jitter.  Safe because the chunk body is
                      atomic — ``self.state`` is only reassigned after the
                      jitted body returns, so a failed attempt leaves the
                      chunk cleanly un-applied.  None disables (errors
                      surface immediately); the fleet supervisor installs
                      its policy on replicas it supervises.
    """
    chunk: int = 256
    path: str = "auto"
    lifecycle: Optional[lifecycle.LifecycleConfig] = None
    drift: Optional[drift_mod.DriftConfig] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    keep_n: int = 3
    vmem_budget: Optional[int] = None
    device: Optional[str] = None
    cost_table: Optional[object] = None
    telemetry_anomaly: bool = False
    telemetry_capacity: int = 4096
    on_nonfinite: str = "drop"
    chunk_retry: Optional[RetryPolicy] = None


class StreamRuntime:
    """Owns mixture state + ingestion loop for one unbounded stream."""

    def __init__(self, cfg: FIGMNConfig,
                 rcfg: RuntimeConfig = RuntimeConfig(),
                 registry: Optional[obs_registry.Registry] = None):
        self.cfg = cfg
        self.rcfg = rcfg
        # obs metrics are process-level by design: N replicas through one
        # registry aggregate into ONE ingest histogram/counter set (what a
        # scrape wants); callers needing isolation pass their own Registry
        reg = registry or obs_registry.default_registry()
        self._m_chunk_s = reg.histogram(
            "figmn_ingest_chunk_seconds",
            "per-chunk ingest wall time (device compute fenced)")
        self._m_points = reg.counter(
            "figmn_ingest_points_total", "points ingested")
        self._m_active = reg.gauge(
            "figmn_active_components", "live mixture components")
        self._m_drift = reg.counter(
            "figmn_drift_alarms_total", "drift detector alarms")
        self._m_lifecycle_s = reg.histogram(
            "figmn_lifecycle_pass_seconds",
            "off-hot-path pool maintenance wall time")
        self._m_pred_s = reg.gauge(
            "figmn_dispatch_predicted_seconds",
            "cost-table expected seconds for one chunk on the chosen path")
        self._m_meas_s = reg.gauge(
            "figmn_dispatch_measured_seconds",
            "last observed per-chunk ingest seconds (pair with "
            "figmn_dispatch_predicted_seconds)")
        self._m_quarantined = reg.counter(
            "figmn_points_quarantined_total",
            "NaN/Inf rows quarantined by the finite guard before they "
            "could touch the mixture")
        self._m_chunk_retries = reg.counter(
            "figmn_chunk_retries_total",
            "chunk ingest attempts retried (recovery-ladder rung 1)")
        # Chunk hooks (fault injection, supervisor heartbeats): objects
        # with optional ``on_chunk_start(chunk_idx, xc_host) ->
        # Optional[replacement_rows]`` (runs BEFORE the finite guard and
        # the ingest body; may raise — the failure enters the chunk-retry
        # ladder) and ``on_chunk_end(chunk_idx, n_points, latency_s)``
        # (observation only, fires after the chunk applied — the
        # supervisor's heartbeat stamp).
        self.chunk_hooks: List[object] = []
        self.state: FIGMNState = figmn.init_state(cfg)
        self.chunk_idx = 0
        # Pool epoch: bumped on EVERY state mutation (chunk ingest,
        # lifecycle pass, drift response, pool import, resume) — the
        # invalidation key for the eq. 27 factor cache.  A read that
        # captures (state, state_epoch) together can safely reuse cached
        # factors for that epoch; any mutation moves new reads to a fresh
        # cache line.
        self.state_epoch = 0
        self.factor_cache = inference.FactorCache(registry=reg)
        # Table-first, heuristic-fallback dispatch (stream.costmodel):
        # bit-compatible with ingest.select_path when rcfg.cost_table is
        # None.  The decision object keeps the expected per-point seconds
        # around for the predicted-vs-measured gauge pair.
        self.dispatch = costmodel.resolve_path(
            cfg, requested=rcfg.path, chunk=rcfg.chunk,
            vmem_budget=rcfg.vmem_budget, device=rcfg.device,
            cost_table=rcfg.cost_table, registry=reg)
        self.path = self.dispatch.path
        self.buffer = lifecycle.FailureBuffer(
            rcfg.lifecycle.buffer_cap if rcfg.lifecycle else 0, cfg.dim)
        self.detector = (drift_mod.DriftDetector(rcfg.drift)
                         if rcfg.drift else None)
        self.telemetry = telemetry.Telemetry(
            capacity=rcfg.telemetry_capacity,
            anomaly=AnomalyDetector(dim=3, warmup=16)
            if rcfg.telemetry_anomaly else None)
        self.ckpt = (CheckpointManager(rcfg.checkpoint_dir,
                                       keep_n=rcfg.keep_n)
                     if rcfg.checkpoint_dir else None)
        self._thresh = jnp.asarray(
            [float(chi2_quantile(cfg.dim, 1.0 - cfg.beta))], jnp.float32)
        # Deferred device→host syncs (see _ingest_chunk): the vmem accept
        # counter stays a device scalar between lifecycle boundaries, and
        # gate-failure masks wait device-side until the spawn pass needs
        # their host rows.
        self._accepted_dev = jnp.zeros((), jnp.int32)
        self._pending_fails = []

    # ------------------------------------------------------------------
    # ingestion
    # ------------------------------------------------------------------

    def ingest(self, xs) -> Dict[str, object]:
        """Feed an (N, D) stream segment; returns the telemetry summary.

        Callable repeatedly — state, telemetry, drift baselines and the
        lifecycle clock all carry across calls (an unbounded stream is just
        many ``ingest`` calls).
        """
        rc = self.rcfg
        with span("stream.ingest", n=int(np.shape(xs)[0]), path=self.path):
            loader = ingest.DoubleBufferedLoader(xs, rc.chunk,
                                                 self.cfg.dtype)
            for xc_dev, xc_host in loader:
                with span("stream.ingest_chunk", path=self.path,
                          n=int(xc_dev.shape[0])):
                    self._ingest_chunk_guarded(xc_dev, xc_host)
            if rc.lifecycle is not None:
                self._run_lifecycle(final=True)
            self._fold_accept_counter()
            if self.ckpt is not None:
                self.checkpoint()
        return self.telemetry.summary()

    def _ingest_chunk_guarded(self, xc_dev: Array,
                              xc_host: np.ndarray) -> None:
        """One chunk through hooks → finite guard → ingest body, under
        the chunk-retry policy (recovery-ladder rung 1).

        Retry is EXACT because the chunk body is atomic: ``_ingest_chunk``
        only reassigns ``self.state`` after the jitted body returns, and
        the hooks/guard run before any mutation — so a failed attempt
        leaves the chunk un-applied and a retry replays it from scratch
        (hooks fire again: a sticky injected fault keeps firing until it
        disarms or the budget escalates the error to the supervisor).
        ``NonFiniteChunkError`` is a policy decision, not a transient
        fault — it surfaces immediately.
        """
        policy = self.rcfg.chunk_retry
        delays = (policy.delays(salt=self.chunk_idx)
                  if policy is not None else iter(()))
        while True:
            try:
                self._ingest_chunk_once(xc_dev, xc_host)
                return
            except ingest.NonFiniteChunkError:
                raise
            except Exception:
                d = next(delays, None)
                if d is None:
                    raise
                self._m_chunk_retries.inc()
                time.sleep(d)

    def _ingest_chunk_once(self, xc_dev: Array,
                           xc_host: np.ndarray) -> None:
        idx = self.chunk_idx
        xh, replaced = xc_host, False
        for h in self.chunk_hooks:
            fn = getattr(h, "on_chunk_start", None)
            if fn is not None:
                rep = fn(idx, xh)
                if rep is not None:
                    xh, replaced = np.asarray(rep, np.float32), True
        xh, n_bad = ingest.finite_guard(xh, self.rcfg.on_nonfinite)
        if n_bad:
            self.telemetry.add_quarantined(n_bad)
            self._m_quarantined.inc(n_bad)
            replaced = True
        t0 = time.perf_counter()
        n_in = int(xh.shape[0])
        if n_in:
            # the all-finite, un-replaced fast path reuses the device copy
            # already in flight — the guard costs one host isfinite sweep
            xd = (jax.device_put(jnp.asarray(xh, self.cfg.dtype))
                  if replaced else xc_dev)
            self._ingest_chunk(xd, xh)
        for h in self.chunk_hooks:
            fn = getattr(h, "on_chunk_end", None)
            if fn is not None:
                # fires for fully-quarantined chunks too (n_in == 0): a
                # replica that is dropping poison is alive, not hung
                fn(idx, n_in, time.perf_counter() - t0)

    def _ingest_chunk(self, xc: Array, xc_host: np.ndarray) -> None:
        rc, cfg = self.rcfg, self.cfg
        # Host-side per-chunk consumers (drift CUSUM, ft.anomaly) genuinely
        # need floats every chunk; everything else (vmem accept counter,
        # gate-failure rows for the spawn buffer) stays device-side until a
        # lifecycle boundary — a per-chunk int()/float() pull would block
        # the host on the device and serialise the double-buffered feed.
        need_stats = self.detector is not None or rc.telemetry_anomaly
        t0 = time.perf_counter()
        n_created0 = int(self.state.n_created)
        formed = bool(jnp.any(self.state.active))
        path = self.path
        if path == "vmem" and not formed:
            path = "scan"            # kernel cannot create the first slot
        need_fails = path == "vmem" and rc.lifecycle is not None

        # Prequential stats: the chunk is scored against the PRE-update
        # mixture ("does the incoming data match what we learned so far").
        # Post-update stats are useless for drift — the single-pass learner
        # adapts within the very chunk that drifted.
        # novelty_rate is a host-side statistic: NaN (like mean_ll) when no
        # per-chunk host consumer exists — on the vmem path the failure
        # mask then stays device-side until the lifecycle boundary, and a
        # fake 0.0 would read as "no novelty observed"
        mean_ll = float("nan")
        novelty_rate = float("nan")
        fails = fails_dev = None
        if (need_stats or need_fails) and formed:
            # shortlisted runtimes keep the stats pass sublinear too — a
            # dense (B, K) sweep here would re-introduce the O(K·D²)
            # per-point cost the sparse body just removed.  Keyed on the
            # RESOLVED path (not cfg.shortlist_c): a forced dense path
            # must see dense gate stats or the spawn buffer would collect
            # points the dense gate actually accepted.
            stats = (shortlist.chunk_stats_sparse if self.path == "sparse"
                     else ingest.chunk_stats)
            fails_dev, mean_ll_dev = stats(
                cfg, self.state, xc, self._thresh[0])
            if need_stats:
                fails = np.asarray(fails_dev)
                novelty_rate = float(fails.mean())
                mean_ll = float(mean_ll_dev)

        if path == "vmem":
            self.state, nacc = ingest.fit_chunk_vmem(cfg, self.state, xc)
            self._accepted_dev = self._accepted_dev + nacc   # device add
            if need_fails:
                if fails is not None:        # already pulled for stats
                    if fails.any():
                        self.buffer.push(xc_host[fails])
                elif fails_dev is not None:  # defer to lifecycle boundary
                    self._pending_fails.append((fails_dev, xc_host))
        else:
            # inline creation/§2.3 pruning ⇔ identical to one-shot fit;
            # with lifecycle enabled, pruning is deferred to the pool pass
            do_prune = rc.lifecycle is None and cfg.spmin > 0
            body = (ingest.fit_chunk_sparse if path == "sparse"
                    else ingest.fit_chunk_scan)
            self.state = body(cfg, self.state, xc, do_prune)
        self.state_epoch += 1

        drift_score, alarm = 0.0, False
        if self.detector is not None and mean_ll == mean_ll:
            drift_score, alarm = self.detector.update(
                mean_ll, novelty_rate, weight=xc.shape[0] / rc.chunk)
            if alarm:
                self._respond_to_drift()

        # the active_k pull doubles as the latency fence: it blocks on this
        # chunk's (donated, async-dispatched) fit, so latency_s includes
        # the device compute on every path — this is the ONE per-chunk
        # device sync the telemetry schema requires (chunk-granular
        # active_k/latency records cannot be deferred without losing them)
        active_k = int(self.state.n_active)
        latency = time.perf_counter() - t0
        self.telemetry.record(telemetry.ChunkMetrics(
            idx=self.chunk_idx, n_points=int(xc.shape[0]),
            active_k=active_k,
            created=int(self.state.n_created) - n_created0,
            mean_ll=mean_ll, novelty_rate=novelty_rate,
            drift_score=float(drift_score), drift_alarm=alarm,
            path=path, latency_s=latency))
        self._m_chunk_s.observe(latency)
        self._m_meas_s.set(latency)
        if self.dispatch.per_point_s is not None:
            # predicted for THIS chunk size — a tail chunk is smaller
            self._m_pred_s.set(self.dispatch.per_point_s
                               * int(xc.shape[0]))
        self._m_points.inc(int(xc.shape[0]))
        self._m_active.set(active_k)
        if alarm:
            self._m_drift.inc()
        self.chunk_idx += 1

        if (rc.lifecycle is not None and rc.lifecycle.every > 0
                and self.chunk_idx % rc.lifecycle.every == 0):
            self._run_lifecycle()
        if (self.ckpt is not None and rc.checkpoint_every > 0
                and self.chunk_idx % rc.checkpoint_every == 0):
            self.checkpoint()

    # ------------------------------------------------------------------
    # lifecycle / drift plumbing
    # ------------------------------------------------------------------

    def _drain_pending_fails(self) -> None:
        """Materialise the deferred gate-failure masks into the spawn
        buffer (the one place their host rows are actually consumed)."""
        for fails_dev, xc_host in self._pending_fails:
            fails = np.asarray(fails_dev)
            if fails.any():
                self.buffer.push(xc_host[fails])
        self._pending_fails.clear()

    def _fold_accept_counter(self) -> None:
        """Pull the device-side vmem accept counter into telemetry — called
        at lifecycle boundaries and end-of-ingest, never per chunk."""
        n = int(self._accepted_dev)
        if n:
            self.telemetry.add_accepted(n)
            self._accepted_dev = jnp.zeros((), jnp.int32)

    def _run_lifecycle(self, final: bool = False) -> None:
        del final  # the pass is identical; the flag only documents intent
        t0 = time.perf_counter()
        with span("stream.lifecycle") as sp:
            self._drain_pending_fails()
            self._fold_accept_counter()
            self.state, rep = lifecycle.run_pass(
                self.cfg, self.rcfg.lifecycle, self.state, self.buffer)
            self.state_epoch += 1
            sp.set(pruned=rep.pruned, merged=rep.merged,
                   spawned=rep.spawned)
        self.telemetry.add_lifecycle(rep.pruned, rep.merged, rep.spawned)
        self._m_lifecycle_s.observe(time.perf_counter() - t0)
        self._m_active.set(int(self.state.n_active))

    def _respond_to_drift(self) -> None:
        dcfg = self.rcfg.drift
        with span("stream.drift_response", response=dcfg.response):
            if dcfg.response == "fork" and self.ckpt is not None:
                # preserve the pre-drift mixture before mutating it
                self.checkpoint()
            self.state = drift_mod.respond(self.cfg, dcfg, self.state)
            self.state_epoch += 1

    # ------------------------------------------------------------------
    # pool export / import (fleet scale events)
    # ------------------------------------------------------------------

    def export_pool(self) -> FIGMNState:
        """The live mixture, for mass-conserving pool moves (fleet
        autoscaling).  Returns a COPY: the chunk-ingest jits donate the
        live state's buffers (Λ reused in place), so handing out the live
        leaves would let the next ingest invalidate them under the holder
        — the copy keeps the documented promise that an exported pool
        survives further ingestion, bit-identically."""
        return jax.tree_util.tree_map(jnp.copy, self.state)

    def import_pool(self, state: FIGMNState) -> None:
        """Replace the live mixture wholesale (fleet scale events: a split
        half on scale-up, the drained union on scale-down).

        Only the pool changes — the chunk clock, telemetry, drift detector
        and spawn buffer stay; but the drift CUSUM's log-likelihood
        baseline belonged to the OLD pool, so its reference window restarts
        (otherwise losing/gaining half the components reads as a fake
        regime change on the very next chunk).
        """
        want = (self.cfg.kmax, self.cfg.dim)
        got = tuple(int(s) for s in state.mu.shape)
        if got != want:
            raise ValueError(f"pool shape {got} != configured {want}")
        # Defensive copy: the chunk-ingest jits DONATE their state buffers
        # (Λ reused in place across chunks), so the runtime must own every
        # buffer privately — an imported pool may alias the exporter's
        # arrays (e.g. the kept half of an autoscale split), and donating a
        # shared buffer would invalidate it under the other holder.
        self.state = jax.tree_util.tree_map(jnp.copy, state)
        self.state_epoch += 1
        if self.detector is not None:
            self.detector.reset_baseline()

    def reset_state(self) -> None:
        """Recovery of last resort: discard the mixture AND the stream
        clocks (telemetry counters, chunk index, drift state, spawn
        buffer) — what the fleet supervisor does when a crashed replica
        has NO intact checkpoint to restore from.  Every point the
        replica had ever ingested is gone; the caller (supervisor) is
        responsible for accounting them as lost, which is why the
        telemetry reset here must be total — a fresh state with stale
        ``total_points`` would double-count in the mass identity."""
        rc = self.rcfg
        self.state = figmn.init_state(self.cfg)
        self.state_epoch += 1
        self.chunk_idx = 0
        self.buffer = lifecycle.FailureBuffer(
            rc.lifecycle.buffer_cap if rc.lifecycle else 0, self.cfg.dim)
        self.detector = (drift_mod.DriftDetector(rc.drift)
                         if rc.drift else None)
        self.telemetry = telemetry.Telemetry(
            capacity=rc.telemetry_capacity,
            anomaly=AnomalyDetector(dim=3, warmup=16)
            if rc.telemetry_anomaly else None)
        self._accepted_dev = jnp.zeros((), jnp.int32)
        self._pending_fails = []

    # ------------------------------------------------------------------
    # scoring / checkpointing
    # ------------------------------------------------------------------

    def score(self, xs) -> Array:
        """(N,) mixture log-densities under the current state (read-only).

        On a shortlisted runtime (resolved path "sparse") the read path is
        sublinear in K too: one (B, K) bound pass + a (B, C) exact pass
        (core.shortlist.score_batch_sparse) instead of the dense (B, K)
        Mahalanobis sweep.  A forced dense ingest path scores densely —
        reads and writes stay consistent."""
        xs = jnp.asarray(xs, self.cfg.dtype)
        if xs.shape[0] == 0:
            # B=0 contract (shared with predict and every serving
            # frontend): well-formed empty output, no device dispatch
            return jnp.zeros((0,), self.cfg.dtype)
        if self.path == "sparse":
            return shortlist.score_batch_sparse(self.cfg, self.state, xs)
        return ingest.score_batch_jit(self.cfg, self.state, xs)

    def predict(self, xs, targets, return_var: bool = False):
        """(N, o) eq. 27 conditional means of ``targets`` given the rest,
        under the current state (read-only; raises on an empty pool).

        Same path contract as ``score``: a shortlisted runtime serves the
        conditional through ``inference.predict_batch_sparse`` (O(K·D +
        C·D²·o) per point, bit-identical to dense at C ≥ active K), a
        dense one through the batched dense kernel.  The factor stage is
        amortised through the runtime's per-epoch ``FactorCache``: repeat
        reads between state mutations reuse the same bundle,
        bit-identically.  return_var=True additionally returns the (N, o)
        conditional variance as a (mean, var) pair."""
        xs = jnp.asarray(xs, self.cfg.dtype)
        return inference.predict_batch_routed(
            self.cfg, self.state, xs, targets,
            c=self.cfg.shortlist_c if self.path == "sparse" else 0,
            cost_table=self.rcfg.cost_table, device=self.rcfg.device,
            return_var=return_var, factor_cache=self.factor_cache,
            epoch=self.state_epoch)

    def _payload(self) -> Dict[str, object]:
        """Everything a resumed runtime needs to continue bit-identically:
        the mixture, the chunk clock, the drift detector's CUSUM/reference
        window (else a resumed replica re-warms up and misses early
        drift), the running telemetry counters (else the summary resets)
        and the pending gate-failure spawn buffer (else the next lifecycle
        pass spawns different components than the uninterrupted run)."""
        payload = {"figmn": self.state,
                   "runtime": {"chunk_idx":
                               jnp.asarray(self.chunk_idx, jnp.int32)},
                   "telemetry": self.telemetry.export_counters()}
        if self.detector is not None:
            payload["drift"] = self.detector.export_state()
        if self.rcfg.lifecycle is not None:
            payload["spawn_buffer"] = self.buffer.export_state()
        return payload

    def checkpoint(self) -> None:
        if self.ckpt is None:
            raise RuntimeError("no checkpoint_dir configured")
        # deferred device-side residue must land before the payload export
        # (the spawn buffer and telemetry counters are part of it)
        self._drain_pending_fails()
        self._fold_accept_counter()
        self.ckpt.save(self.chunk_idx, self._payload())
        self.ckpt.wait()

    def resume(self, step: Optional[int] = None) -> bool:
        """Restore a checkpoint (latest by default); True if one existed.

        step: restore this exact step instead — the fleet coordinator pins
        per-replica steps in its manifest so a resumed fleet is a
        consistent cut even when replicas auto-checkpointed after the last
        manifest write.
        """
        if self.ckpt is None:
            raise RuntimeError("no checkpoint_dir configured")
        if step is None:
            # newest INTACT step: auto-resume must never pick a payload
            # whose content hashes no longer match its manifest when an
            # earlier verified step exists (crash-recovery semantics)
            step = self.ckpt.latest_step(verify=True)
        elif step not in self.ckpt.all_steps():
            return False
        if step is None:
            return False
        template = {"figmn": figmn.init_state(self.cfg),
                    "runtime": {"chunk_idx": jnp.zeros((), jnp.int32)},
                    "telemetry": telemetry.Telemetry.counters_template()}
        if self.detector is not None:
            template["drift"] = drift_mod.DriftDetector.state_template(
                self.rcfg.drift)
        if self.rcfg.lifecycle is not None:
            template["spawn_buffer"] = lifecycle.FailureBuffer \
                .state_template(self.buffer.cap, self.cfg.dim)
        # missing="template": checkpoints from an older payload format
        # restore what they have; newer sections start fresh (zeros)
        loaded = self.ckpt.restore(step, template, missing="template")
        self.state = loaded["figmn"]
        self.state_epoch += 1
        self.chunk_idx = int(loaded["runtime"]["chunk_idx"])
        self.telemetry.load_counters(loaded["telemetry"])
        if self.detector is not None:
            self.detector.load_state(loaded["drift"])
        if self.rcfg.lifecycle is not None:
            self.buffer.load_state(loaded["spawn_buffer"])
        return True
