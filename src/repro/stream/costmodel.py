"""Device-calibrated dispatch cost model — measured ``select_path``.

ROADMAP item 2 calls the VMEM heuristic in ``ingest.select_path`` "a
guess".  This module replaces the guesswork with measurement while keeping
the guess as the bit-compatible fallback:

  * ``calibrate`` times every dispatch path (scan / sparse / vmem ingest;
    dense / sparse score and eq. 27 predict) over a (K, D, C, chunk) grid
    on the ACTUAL backend — compile-excluded, ``block_until_ready``-fenced,
    median-of-R (obs.prof) — and pairs each measurement with an
    HLO-derived roofline prediction (distributed.hlo_analysis on the
    compiled module), producing a ``CostTable``.
  * ``CostTable`` is persisted as versioned JSON (obs.export.to_json),
    keyed by ``(device_kind, jax_version)`` so a table calibrated on one
    machine never silently drives decisions on different hardware, and
    mergeable across runs/devices (same-cell conflicts keep the faster
    measurement — re-calibration can only sharpen a table).
  * ``decide`` / ``resolve_path`` are what the runtime, fleet coordinator
    and Mixture facade consult at resolve time: forced paths stay forced;
    with no table (or no cells for this device key) the decision IS
    ``ingest.select_path``'s heuristic, bit-compatibly; with a table, the
    path with the smallest measured per-point seconds wins among the
    SAFE candidates — the vmem candidacy guard (exact update mode,
    working set ≤ VMEM budget, TPU backend) is a launch-correctness
    constraint and survives calibration, so an oversized working set can
    never select "vmem" no matter what a table claims.
  * ``resolve_path`` additionally exports the decision layer:
    ``figmn_dispatch_decisions_total{path,reason}``,
    ``figmn_vmem_budget_bytes``, ``figmn_dispatch_predicted_seconds`` and
    a ``dispatch.resolve`` span in the obs trace stream.

The VMEM budget itself stops being a constant where the backend can be
asked: ``resolve_vmem_budget`` queries the device's memory stats for a
VMEM capacity and only then falls back to ``ingest.DEFAULT_VMEM_BUDGET``
(CPU's ``memory_stats()`` is None ⇒ the constant, which is what keeps
no-table CPU decisions bit-identical to the PR-6 heuristic).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import figmn, shortlist
from repro.core.types import FIGMNConfig
from repro.obs import export as obs_export
from repro.obs import prof
from repro.obs import registry as obs_registry
from repro.obs.trace import span
from repro.stream import ingest

#: bump when the CostTable cell/envelope shape changes; ``CostTable.load``
#: refuses versions it does not know (misparsing a table would silently
#: redirect production dispatch).
TABLE_VERSION = 1

#: device memory_stats keys that plausibly expose a VMEM-like capacity,
#: in preference order (backend-dependent; absent on CPU).
_VMEM_STAT_KEYS = ("vmem_size_bytes", "vmem_bytes_limit", "vmem_size")


def resolve_backend(device: Optional[str] = None) -> str:
    """The backend a dispatch decision is for: an explicit platform name
    ("cpu"/"gpu"/"tpu") wins, else the process default — threading this
    through configs is what makes dispatch device-aware instead of keyed
    off one global."""
    return device if device else jax.default_backend()


def device_key(device: Optional[str] = None) -> str:
    """``"<device_kind>|jax-<version>"`` — the CostTable entry key.

    device_kind (e.g. "TPU v4", "cpu") pins the hardware; the jax version
    pins the compiler generation (the same path can flip winners across
    XLA releases).  A checkpoint restored on different hardware therefore
    re-resolves from its own entries — or falls back to the heuristic —
    instead of replaying a stale decision.
    """
    backend = resolve_backend(device)
    try:
        kind = jax.devices(backend)[0].device_kind
    except Exception:
        kind = backend
    return f"{kind}|jax-{jax.__version__}"


def resolve_vmem_budget(explicit: Optional[int] = None,
                        device: Optional[str] = None) -> Tuple[int, str]:
    """→ (bytes, source) with source ∈ {"config", "device", "default"}.

    An explicit budget always wins (operator override).  Otherwise ask the
    device: backends that expose a VMEM-like capacity in
    ``memory_stats()`` get a measured budget; the guessed 12 MiB constant
    is the FINAL fallback only (and the one CPU takes, where
    ``memory_stats()`` is None — keeping no-table CPU decisions
    bit-identical to the constant-budget heuristic).
    """
    if explicit is not None:
        return int(explicit), "config"
    try:
        stats = jax.devices(resolve_backend(device))[0].memory_stats()
    except Exception:
        stats = None
    if stats:
        for key in _VMEM_STAT_KEYS:
            if key in stats and int(stats[key]) > 0:
                return int(stats[key]), "device"
    return ingest.DEFAULT_VMEM_BUDGET, "default"


# ---------------------------------------------------------------------------
# CostTable
# ---------------------------------------------------------------------------

def _cell_key(cell: Dict) -> Tuple:
    return (cell["kind"], cell["path"], int(cell["k"]), int(cell["d"]),
            int(cell.get("c", 0)), int(cell["n"]))


def _log_dist(cell: Dict, k: int, d: int, c: int, n: int) -> float:
    """Nearest-cell metric: squared distance in log1p space over the
    (K, D, C, n) axes — multiplicative regimes, not absolute deltas,
    decide which calibration point a config resembles."""
    tot = 0.0
    for have, want in ((cell["k"], k), (cell["d"], d),
                       (cell.get("c", 0), c), (cell["n"], n)):
        tot += (math.log1p(float(have)) - math.log1p(float(want))) ** 2
    return tot


class CostTable:
    """Measured per-path costs, keyed by device, mergeable across runs.

    ``entries`` maps ``device_key()`` strings to lists of cells::

        {"kind": "ingest"|"score"|"predict", "path": str,
         "k": int, "d": int, "c": int, "n": int,
         "measured_s": float, "per_point_s": float,
         "hlo": {"flops": ..., "traffic_bytes": ...} | None,
         "compute_s"/"memory_s"/"predicted_s": float | None,
         "bottleneck": "compute"|"memory" | None}
    """

    def __init__(self, entries: Optional[Dict[str, List[Dict]]] = None,
                 meta: Optional[Dict] = None):
        self.entries: Dict[str, List[Dict]] = {
            k: list(v) for k, v in (entries or {}).items()}
        self.meta: Dict = dict(meta or {})

    # -- construction --------------------------------------------------

    def add_cell(self, dkey: str, cell: Dict) -> None:
        """Insert/replace one cell (same cell key ⇒ keep the faster
        measurement — the merge rule, applied incrementally)."""
        cells = self.entries.setdefault(dkey, [])
        key = _cell_key(cell)
        for i, have in enumerate(cells):
            if _cell_key(have) == key:
                if cell["measured_s"] < have["measured_s"]:
                    cells[i] = dict(cell)
                return
        cells.append(dict(cell))

    def merge(self, other: "CostTable") -> "CostTable":
        """Union of device keys; duplicate cells keep the faster
        measurement (medians only over-estimate under interference, so
        min is the honest combinator).  Returns a NEW table."""
        out = CostTable(self.entries, self.meta)
        for dkey, cells in other.entries.items():
            for cell in cells:
                out.add_cell(dkey, cell)
        merged_meta = dict(other.meta)
        merged_meta.update(out.meta)   # self.meta wins on conflicts
        out.meta = merged_meta
        return out

    # -- lookup --------------------------------------------------------

    def cells(self, dkey: str, kind: Optional[str] = None,
              path: Optional[str] = None) -> List[Dict]:
        return [c for c in self.entries.get(dkey, ())
                if (kind is None or c["kind"] == kind)
                and (path is None or c["path"] == path)]

    def lookup(self, dkey: str, kind: str, path: str, *, k: int, d: int,
               c: int = 0, n: int = 1) -> Optional[Dict]:
        """Nearest calibrated cell for (kind, path) in log-(K, D, C, n)
        space; deterministic tie-break on the cell key so equal-distance
        grids resolve identically across processes."""
        cands = self.cells(dkey, kind, path)
        if not cands:
            return None
        return min(cands, key=lambda cell: (_log_dist(cell, k, d, c, n),
                                            _cell_key(cell)))

    def device_keys(self) -> List[str]:
        return sorted(self.entries)

    # -- persistence ---------------------------------------------------

    def to_doc(self) -> Dict:
        return {"cost_table_version": TABLE_VERSION,
                "meta": self.meta, "entries": self.entries}

    def save(self, path: str) -> None:
        obs_export.to_json(path, self.to_doc())

    @classmethod
    def from_doc(cls, doc: Dict) -> "CostTable":
        ver = doc.get("cost_table_version")
        if ver != TABLE_VERSION:
            raise ValueError(
                f"unknown cost table version {ver!r} (this build reads "
                f"version {TABLE_VERSION}); re-calibrate or upgrade")
        return cls(entries=doc.get("entries", {}), meta=doc.get("meta", {}))

    @classmethod
    def load(cls, path: str) -> "CostTable":
        with open(path) as f:
            return cls.from_doc(json.load(f))

    @classmethod
    def from_any(cls, obj) -> Optional["CostTable"]:
        """None | CostTable | path-to-JSON — what configs may carry."""
        if obj is None or isinstance(obj, cls):
            return obj
        if isinstance(obj, str):
            return cls.load(obj)
        if isinstance(obj, dict):
            return cls.from_doc(obj)
        raise TypeError(f"cannot interpret {type(obj).__name__} as a "
                        f"CostTable (want None, CostTable, dict or path)")


# ---------------------------------------------------------------------------
# decisions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """One resolved dispatch, with enough context to explain itself."""
    path: str                 # chosen body
    reason: str               # "forced" | "heuristic" | "no_table_entry"
    #                         # | "table"
    heuristic_path: str       # what the PR-6 heuristic would have chosen
    device_key: str
    backend: str
    vmem_budget: int
    vmem_source: str          # "config" | "device" | "default"
    per_point_s: Optional[float] = None
    predicted_s: Optional[float] = None   # HLO roofline seconds (cell)
    measured_s: Optional[float] = None
    bottleneck: Optional[str] = None
    cell: Optional[Dict] = None
    candidates: Dict[str, float] = dataclasses.field(default_factory=dict)


def _vmem_candidate_ok(cfg: FIGMNConfig, budget: int, backend: str) -> bool:
    """The launch-correctness guard the vmem kernel requires — identical
    to the heuristic's condition and NOT overridable by a table."""
    working_set = cfg.kmax * cfg.dim * cfg.dim * 4
    return (cfg.update_mode == "exact" and working_set <= budget
            and backend == "tpu")


def decide(cfg: FIGMNConfig, *, requested: str = "auto", chunk: int = 256,
           vmem_budget: Optional[int] = None, device: Optional[str] = None,
           cost_table=None) -> DispatchDecision:
    """Resolve the ingest dispatch path, table-first, heuristic-fallback.

    Pure (no metrics, no spans) — safe from ``__repr__``s and tests;
    ``resolve_path`` is the recording twin the engines call once per
    construction.  Bit-compat contract (pinned in tests/test_costmodel.py):
    with ``cost_table=None`` the returned ``path`` equals
    ``ingest.select_path(cfg, vmem_budget=..., requested=...)`` exactly,
    for every (cfg, requested, budget, device) combination.
    """
    backend = resolve_backend(device)
    budget, source = resolve_vmem_budget(vmem_budget, device)
    heuristic = ingest.select_path(cfg, vmem_budget=budget,
                                   requested=requested, device=backend)
    dkey = device_key(device)
    base = dict(heuristic_path=heuristic, device_key=dkey, backend=backend,
                vmem_budget=budget, vmem_source=source)
    if requested != "auto":
        return DispatchDecision(path=heuristic, reason="forced", **base)
    table = CostTable.from_any(cost_table)
    if table is None:
        return DispatchDecision(path=heuristic, reason="heuristic", **base)
    candidates = ["scan"]
    if cfg.shortlist_c > 0:
        candidates.append("sparse")
    if _vmem_candidate_ok(cfg, budget, backend):
        candidates.append("vmem")
    found: Dict[str, Dict] = {}
    for path in candidates:
        c = cfg.shortlist_c if path == "sparse" else 0
        cell = table.lookup(dkey, "ingest", path, k=cfg.kmax, d=cfg.dim,
                            c=c, n=chunk)
        if cell is not None:
            found[path] = cell
    if not found:
        return DispatchDecision(path=heuristic, reason="no_table_entry",
                                **base)
    best = min(found, key=lambda p: (found[p]["per_point_s"], p))
    cell = found[best]
    return DispatchDecision(
        path=best, reason="table",
        per_point_s=float(cell["per_point_s"]),
        predicted_s=cell.get("predicted_s"),
        measured_s=float(cell["measured_s"]),
        bottleneck=cell.get("bottleneck"), cell=cell,
        candidates={p: float(found[p]["per_point_s"]) for p in found},
        **base)


def resolve_path(cfg: FIGMNConfig, *, requested: str = "auto",
                 chunk: int = 256, vmem_budget: Optional[int] = None,
                 device: Optional[str] = None, cost_table=None,
                 registry: Optional[obs_registry.Registry] = None
                 ) -> DispatchDecision:
    """``decide`` + the observability exports (one call per engine build):
    decision counter, VMEM-budget gauge, predicted-seconds gauge and a
    ``dispatch.resolve`` span in the trace stream."""
    d = decide(cfg, requested=requested, chunk=chunk,
               vmem_budget=vmem_budget, device=device,
               cost_table=cost_table)
    reg = registry or obs_registry.default_registry()
    reg.counter("figmn_dispatch_decisions_total",
                "dispatch resolutions by chosen path and decision source",
                {"path": d.path, "reason": d.reason}).inc()
    reg.gauge("figmn_vmem_budget_bytes",
              "VMEM budget the kernel-launch guard compares against "
              "(source: config override, device query, or the 12 MiB "
              "default)").set(d.vmem_budget)
    if d.per_point_s is not None:
        reg.gauge("figmn_dispatch_predicted_seconds",
                  "cost-table expected seconds for one chunk on the "
                  "chosen path (pair with figmn_dispatch_measured_seconds)"
                  ).set(d.per_point_s * chunk)
    with span("dispatch.resolve", path=d.path, reason=d.reason,
              heuristic=d.heuristic_path, backend=d.backend,
              vmem_budget=d.vmem_budget):
        pass
    return d


def decide_predict(cfg: FIGMNConfig, *, c: int, n: int = 512,
                   device: Optional[str] = None, cost_table=None
                   ) -> DispatchDecision:
    """The dense-vs-sparse eq. 27 predict routing (the ``c`` switch in
    ``inference.predict_batch_routed``), table-aware.

    Heuristic (and the c<=0 / no-table behaviour, bit-compat with PR 6):
    sparse whenever a shortlist width was resolved.  With a table, the
    measured faster of {dense, sparse@c} wins — at small K the bound
    pass + gather overhead can beat its own savings, which is exactly
    the regime flip a heuristic cannot see.
    """
    backend = resolve_backend(device)
    dkey = device_key(device)
    heuristic = "sparse" if c > 0 else "dense"
    base = dict(heuristic_path=heuristic, device_key=dkey, backend=backend,
                vmem_budget=0, vmem_source="config")
    if c <= 0:
        return DispatchDecision(path="dense", reason="forced", **base)
    table = CostTable.from_any(cost_table)
    if table is None:
        return DispatchDecision(path=heuristic, reason="heuristic", **base)
    found: Dict[str, Dict] = {}
    for path, cc in (("dense", 0), ("sparse", c)):
        cell = table.lookup(dkey, "predict", path, k=cfg.kmax, d=cfg.dim,
                            c=cc, n=n)
        if cell is not None:
            found[path] = cell
    if len(found) < 2:
        return DispatchDecision(path=heuristic, reason="no_table_entry",
                                **base)
    best = min(found, key=lambda p: (found[p]["per_point_s"], p))
    cell = found[best]
    return DispatchDecision(
        path=best, reason="table",
        per_point_s=float(cell["per_point_s"]),
        predicted_s=cell.get("predicted_s"),
        measured_s=float(cell["measured_s"]),
        bottleneck=cell.get("bottleneck"), cell=cell,
        candidates={p: float(found[p]["per_point_s"]) for p in found},
        **base)


def resolve_predict(cfg: FIGMNConfig, *, c: int, n: int = 512,
                    device: Optional[str] = None, cost_table=None,
                    registry: Optional[obs_registry.Registry] = None
                    ) -> DispatchDecision:
    """Recording twin of ``decide_predict`` (path label prefixed
    ``predict_`` so serving decisions don't alias ingest ones)."""
    d = decide_predict(cfg, c=c, n=n, device=device, cost_table=cost_table)
    reg = registry or obs_registry.default_registry()
    reg.counter("figmn_dispatch_decisions_total",
                "dispatch resolutions by chosen path and decision source",
                {"path": f"predict_{d.path}", "reason": d.reason}).inc()
    return d


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

#: (K, D, (C...)) calibration grid; chunk sizes and serve batch ride along.
DEFAULT_GRID: Tuple = ((64, 16, (8,)), (256, 32, (8, 16)))
SMOKE_GRID: Tuple = ((16, 8, (4,)),)


def _synth(n: int, d: int, modes: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 8.0, (modes, d))
    x = centers[rng.integers(0, modes, n)] + rng.normal(0, 1.0, (n, d))
    return x.astype(np.float32)


def _calib_cfg(x: np.ndarray, kmax: int, c: int = 0) -> FIGMNConfig:
    return FIGMNConfig(kmax=kmax, dim=x.shape[1], beta=0.1, delta=1.0,
                       vmin=1e9, spmin=0.0, update_mode="exact",
                       shortlist_c=c,
                       sigma_ini=figmn.sigma_from_data(jnp.asarray(x), 1.0))


def _copy_state(state):
    return jax.tree_util.tree_map(jnp.copy, state)


def _mk_cell(kind: str, path: str, k: int, d: int, c: int, n: int,
             measured_s: float, hlo: Optional[Dict], backend: str) -> Dict:
    cell = {"kind": kind, "path": path, "k": int(k), "d": int(d),
            "c": int(c), "n": int(n), "measured_s": float(measured_s),
            "per_point_s": float(measured_s) / max(int(n), 1),
            "hlo": ({"flops": hlo.get("flops", 0.0),
                     "traffic_bytes": hlo.get("traffic_bytes", 0.0)}
                    if hlo else None),
            "compute_s": None, "memory_s": None, "predicted_s": None,
            "bottleneck": None}
    terms = prof.roofline_terms(hlo, backend)
    if terms:
        cell.update(terms)
    return cell


def calibrate(grid: Sequence = DEFAULT_GRID, *,
              chunks: Sequence[int] = (256,), n_serve: int = 1024,
              repeats: int = 3, device: Optional[str] = None,
              include_vmem: Optional[bool] = None, seed: int = 0,
              base: Optional[CostTable] = None,
              verbose: bool = False) -> CostTable:
    """Measure every dispatch path over a (K, D, C, chunk) grid → table.

    Each (K, D) point fits a warm pool once (steady-state dispatch serves
    a formed mixture, not the creation burst), then times each body from
    copies of that pool (the fit jits donate their state).  ``include_vmem``
    defaults to TPU-only: in interpret mode the Pallas body is a
    correctness path whose timing would poison the table.  ``base`` merges
    the new cells into an existing table (cross-run accumulation).
    """
    from repro.core import inference   # predict kernels (no import cycle)

    backend = resolve_backend(device)
    dkey = device_key(device)
    if include_vmem is None:
        include_vmem = backend == "tpu"
    table = CostTable(meta={"backend": backend, "device_key": dkey,
                            "jax_version": jax.__version__,
                            "grid": [list(g[:2]) + [list(g[2])]
                                     for g in grid],
                            "chunks": list(chunks), "n_serve": int(n_serve),
                            "repeats": int(repeats)})

    for kmax, d, cs in grid:
        modes = min(max(kmax // 4, 2), 16)
        warm_n = max(max(chunks), 512)
        xw = _synth(warm_n, d, modes, seed=seed)
        cfg_dense = _calib_cfg(xw, kmax)
        warm = figmn.fit(cfg_dense, figmn.init_state(cfg_dense),
                         jnp.asarray(xw))
        serve = jnp.asarray(_synth(n_serve, d, modes, seed=seed + 11))
        serve_in = serve[:, :d - 1]
        targets = (d - 1,)

        for n in chunks:
            xc = jnp.asarray(xw[:n])

            with span("costmodel.calibrate_cell", k=kmax, d=d, n=n):
                t = prof.median_time(
                    figmn.fit, lambda: (cfg_dense, _copy_state(warm), xc),
                    repeats=repeats)
                hlo = prof.hlo_cost(
                    lambda s, x: figmn.fit(cfg_dense, s, x), warm, xc)
                table.add_cell(dkey, _mk_cell(
                    "ingest", "scan", kmax, d, 0, n, t, hlo, backend))

                for c in cs:
                    cfg_c = dataclasses.replace(cfg_dense, shortlist_c=c)
                    t = prof.median_time(
                        shortlist.fit_sparse,
                        lambda: (cfg_c, _copy_state(warm), xc),
                        repeats=repeats)
                    hlo = prof.hlo_cost(
                        lambda s, x: shortlist.fit_sparse(cfg_c, s, x),
                        warm, xc)
                    table.add_cell(dkey, _mk_cell(
                        "ingest", "sparse", kmax, d, c, n, t, hlo, backend))

                if include_vmem and _vmem_candidate_ok(
                        cfg_dense, resolve_vmem_budget(None, device)[0],
                        backend):
                    t = prof.median_time(
                        ingest.fit_chunk_vmem,
                        lambda: (cfg_dense, _copy_state(warm), xc),
                        repeats=repeats)
                    table.add_cell(dkey, _mk_cell(
                        "ingest", "vmem", kmax, d, 0, n, t, None, backend))

        # serving reads: dense vs sparse score, dense vs sparse predict
        with span("costmodel.calibrate_serve", k=kmax, d=d, n=n_serve):
            t = prof.median_time(ingest.score_batch_jit,
                                 lambda: (cfg_dense, warm, serve),
                                 repeats=repeats)
            hlo = prof.hlo_cost(
                lambda s, x: figmn.score_batch(cfg_dense, s, x),
                warm, serve)
            table.add_cell(dkey, _mk_cell(
                "score", "dense", kmax, d, 0, n_serve, t, hlo, backend))

            t = prof.median_time(
                inference.predict_batch,
                lambda: (cfg_dense, warm, serve_in, targets),
                repeats=repeats)
            hlo = prof.hlo_cost(
                lambda s, x: inference._predict_dense_jit(
                    inference._factors_jit(cfg_dense, s, targets),
                    s.sp, s.active, x), warm, serve_in)
            table.add_cell(dkey, _mk_cell(
                "predict", "dense", kmax, d, 0, n_serve, t, hlo, backend))

            for c in cs:
                cfg_c = dataclasses.replace(cfg_dense, shortlist_c=c)
                t = prof.median_time(
                    shortlist.score_batch_sparse,
                    lambda: (cfg_c, warm, serve), repeats=repeats)
                hlo = prof.hlo_cost(
                    lambda s, x: shortlist.score_batch_sparse(cfg_c, s, x),
                    warm, serve)
                table.add_cell(dkey, _mk_cell(
                    "score", "sparse", kmax, d, c, n_serve, t, hlo,
                    backend))

                t = prof.median_time(
                    inference.predict_batch_sparse,
                    lambda: (cfg_c, warm, serve_in, targets, c),
                    repeats=repeats)
                hlo = prof.hlo_cost(
                    lambda s, x: inference._predict_sparse_jit(
                        cfg_c, inference._factors_jit(cfg_c, s, targets),
                        s.sp, s.active, x, c), warm, serve_in)
                table.add_cell(dkey, _mk_cell(
                    "predict", "sparse", kmax, d, c, n_serve, t, hlo,
                    backend))

        if verbose:
            print(f"calibrated K={kmax} D={d} Cs={tuple(cs)} "
                  f"({len(table.entries[dkey])} cells)")

    if base is not None:
        table = base.merge(table)
    return table


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

def to_roofline_records(table: CostTable,
                        dkey: Optional[str] = None) -> List[Dict]:
    """CostTable cells as ``benchmarks/roofline.py`` ``figmn_path``
    records — the measured-vs-predicted roofline view of the table.  Cells
    without an HLO analysis (Pallas bodies) are skipped."""
    recs = []
    for key in ([dkey] if dkey else table.device_keys()):
        for cell in table.entries.get(key, ()):
            if not cell.get("hlo"):
                continue
            h = dict(cell["hlo"])
            h.setdefault("coll_bytes_total", 0.0)
            backend = table.meta.get("backend", "cpu")
            peaks = prof.backend_peaks(backend)
            recs.append({
                "peak_flops": peaks.flops, "hbm_bw": peaks.hbm_bw,
                "arch": "figmn-path",
                "shape": (f"{cell['kind']}-{cell['path']}"
                          f"_k{cell['k']}_d{cell['d']}"
                          f"_c{cell.get('c', 0)}_n{cell['n']}"),
                "mesh": "1x1", "n_devices": 1, "model_axis": 1,
                "kind": "figmn_path", "op": cell["kind"],
                "path": cell["path"], "k": cell["k"], "d": cell["d"],
                "c": cell.get("c", 0), "points": cell["n"],
                "hlo": h, "memory": {}, "device_key": key,
                "measured_s": cell["measured_s"]})
    return recs


def explain(cfg: FIGMNConfig, *, requested: str = "auto", chunk: int = 256,
            vmem_budget: Optional[int] = None, device: Optional[str] = None,
            cost_table=None) -> str:
    """Human-readable dispatch report (``launch/serve.py
    --explain-dispatch``): the decision, the heuristic counterfactual, the
    backing table row and its roofline bottleneck term."""
    d = decide(cfg, requested=requested, chunk=chunk,
               vmem_budget=vmem_budget, device=device,
               cost_table=cost_table)
    lines = [
        f"dispatch: path={d.path!r} reason={d.reason!r} "
        f"(K={cfg.kmax} D={cfg.dim} C={cfg.shortlist_c} chunk={chunk})",
        f"  device_key: {d.device_key} (backend={d.backend})",
        f"  vmem_budget: {d.vmem_budget} bytes ({d.vmem_source}) — "
        f"working set {cfg.kmax * cfg.dim * cfg.dim * 4} bytes",
        f"  heuristic counterfactual: {d.heuristic_path!r}"
        + (" (table overrode it)" if d.path != d.heuristic_path else
           " (agrees)"),
    ]
    if d.cell is not None:
        cell = d.cell
        lines.append(
            f"  table row: kind={cell['kind']} path={cell['path']} "
            f"k={cell['k']} d={cell['d']} c={cell.get('c', 0)} "
            f"n={cell['n']} measured={cell['measured_s']:.3e}s")
        if cell.get("predicted_s") is not None:
            ratio = cell["measured_s"] / max(cell["predicted_s"], 1e-30)
            lines.append(
                f"  roofline: predicted={cell['predicted_s']:.3e}s "
                f"(bottleneck={cell.get('bottleneck')}, "
                f"measured/predicted={ratio:.1f}x)")
    if d.candidates:
        ranked = sorted(d.candidates.items(), key=lambda kv: kv[1])
        lines.append("  candidates: " + " | ".join(
            f"{p} {v:.3e} s/pt" for p, v in ranked))
    if d.reason in ("heuristic", "no_table_entry"):
        lines.append("  (no usable table for this device key: decisions "
                     "are the PR-6 heuristic, bit-compatibly — run "
                     "benchmarks.figmn_dispatch to calibrate)")
    return "\n".join(lines)
