"""Component-pool lifecycle under a fixed K budget (off the hot path).

§2.3 of the paper gives the spawn/prune rules; what it does not give is a
schedule.  Running shape-changing work per point would force retraces and
serialise the stream, so — following the scalable follow-up (Pinto & Engel
2017, where the component budget is the central knob) — all lifecycle work
runs every ``lifecycle_every`` chunks on host-side Python, leaving the
jitted per-chunk bodies shape-static:

  spawn  — replay points from the gate-failure buffer through learn_one
           (Algorithm 3 creates a component iff the point still fails the
           gate — points explained by components spawned earlier in the
           same pass update instead of duplicating),
  prune  — §2.3 age/mass rule (figmn.prune),
  merge  — while the pool exceeds ``k_budget``: moment-match the two most
           similar components (core.merge.closest_pair /
           moment_match_pair) — O(D³) but rare, so the amortised per-point
           cost stays O(KD²).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import figmn, merge
from repro.core.types import FIGMNConfig, FIGMNState
from repro.stream import ingest


@dataclasses.dataclass(frozen=True)
class LifecycleConfig:
    """Policy knobs for pool management.

    k_budget: max live components after a lifecycle pass (0 ⇒ cfg.kmax).
    every:    chunks between passes.
    spawn_max: buffered gate-failure points replayed per pass.
    buffer_cap: gate-failure ring-buffer capacity (host memory).
    prune/merge_down: enable the §2.3 prune rule / budget merging.
    """
    k_budget: int = 0
    every: int = 8
    spawn_max: int = 4
    buffer_cap: int = 256
    prune: bool = True
    merge_down: bool = True


@dataclasses.dataclass
class LifecycleReport:
    spawned: int = 0
    pruned: int = 0
    merged: int = 0
    active_k: int = 0


class FailureBuffer:
    """Host-side ring buffer of gate-failing points (spawn candidates)."""

    def __init__(self, cap: int, dim: int):
        self.cap = int(cap)
        self.dim = int(dim)
        self._items: List[np.ndarray] = []

    def __len__(self) -> int:
        return len(self._items)

    def push(self, xs: np.ndarray) -> None:
        if self.cap <= 0:                        # no lifecycle ⇒ no buffer
            return
        for x in np.atleast_2d(np.asarray(xs, np.float32)):
            self._items.append(x)
        if len(self._items) > self.cap:          # drop oldest
            self._items = self._items[-self.cap:]

    def drain(self, k: Optional[int] = None) -> np.ndarray:
        k = len(self._items) if k is None else min(k, len(self._items))
        out, self._items = self._items[:k], self._items[k:]
        return np.asarray(out, np.float32).reshape(k, self.dim)

    # -- checkpoint round-trip (fixed (cap, dim) shape so the manager's
    # -- manifest doesn't depend on the current fill level) ---------------

    def export_state(self):
        arr = np.zeros((self.cap, self.dim), np.float32)
        if self._items:
            arr[:len(self._items)] = np.stack(self._items)
        return {"buf": arr,
                "count": np.asarray(len(self._items), np.int64)}

    def load_state(self, payload) -> None:
        n = int(payload["count"])
        arr = np.asarray(payload["buf"], np.float32)
        self._items = [arr[i].copy() for i in range(n)]

    @staticmethod
    def state_template(cap: int, dim: int):
        return {"buf": np.zeros((cap, dim), np.float32),
                "count": np.zeros((), np.int64)}


def run_pass(cfg: FIGMNConfig, lcfg: LifecycleConfig, state: FIGMNState,
             buffer: Optional[FailureBuffer] = None
             ) -> Tuple[FIGMNState, LifecycleReport]:
    """One lifecycle pass: prune → spawn → merge-to-budget."""
    rep = LifecycleReport()
    k_budget = lcfg.k_budget or cfg.kmax

    if lcfg.prune and cfg.spmin > 0:
        before = int(state.n_active)
        state = figmn.prune(cfg, state)
        rep.pruned = before - int(state.n_active)

    if buffer is not None and len(buffer):
        for x in buffer.drain(lcfg.spawn_max):
            state = ingest.learn_one_jit(cfg, state, jnp.asarray(x),
                                         do_prune=False)
            rep.spawned += 1

    if lcfg.merge_down:
        state, rep.merged = merge.merge_to_budget(cfg, state, k_budget)

    rep.active_k = int(state.n_active)
    return state, rep
