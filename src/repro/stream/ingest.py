"""Micro-batch ingestion: chunking, double-buffered H2D, path dispatch.

Implements the stream side of Algorithm 1: the learner itself is strictly
sequential in the data (that IS the IGMN), so the only ingestion freedoms
are (a) when host→device transfers happen and (b) which compiled body
consumes a chunk.  Two bodies exist:

  "scan"  — per-chunk ``lax.scan`` over ``core.figmn.learn_one`` (the
            reference O(KD²) path, eqs. 3–10/20–26; handles creation and
            pruning inline, so chunked ingestion is bit-identical to one
            ``core.figmn.fit`` call over the concatenated stream),
  "vmem"  — the VMEM-resident Pallas kernel ``kernels.figmn_stream``: the
            whole (K, D, D) working set stays in VMEM scratch for the whole
            chunk and HBM is touched only for the x_t vectors (DESIGN
            lineage in the kernel's module docstring).  Creation events are
            no-ops inside the kernel; gate-failing points are surfaced to
            the caller for the lifecycle spawn buffer.
  "sparse"— the top-C shortlist body (``core.shortlist.fit_sparse``):
            per point an O(K·D) bound pass selects C candidate components
            and the exact O(D²) work (matvec, posterior, fused rank-one
            update) runs on the C gathered rows only — O(K·D + C·D²)
            instead of O(K·D²).  Handles creation and pruning inline like
            "scan" and is BIT-IDENTICAL to it when C ≥ active K.

``select_path`` picks between them: the sparse body whenever the config
enables a shortlist (cfg.shortlist_c > 0 — the biggest K-scaling lever),
else the vmem kernel under a VMEM-budget heuristic (only profitable — and
only correct to launch — when the working set K·D²·4B fits the budget, the
update mode is the PSD-safe "exact" one, and we are actually on a TPU; in
interpret mode the kernel is a correctness path, not a fast path), else
the scan reference.
"""
from __future__ import annotations

from functools import partial
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import figmn, shortlist
from repro.core.types import Array, FIGMNConfig, FIGMNState, chi2_quantile
from repro.kernels import figmn_stream

#: Conservative per-core VMEM available to the resident kernel (bytes).
DEFAULT_VMEM_BUDGET = 12 * 2 ** 20


def select_path(cfg: FIGMNConfig, *,
                vmem_budget: Optional[int] = DEFAULT_VMEM_BUDGET,
                requested: str = "auto",
                device: Optional[str] = None) -> str:
    """Choose the per-chunk dispatch path ("scan" | "vmem" | "sparse").

    requested: "scan"/"vmem"/"sparse" force a path; "auto" applies the
    heuristic.  A forced "sparse" requires cfg.shortlist_c > 0 (the width
    is a config property, not a runtime knob — jitted shapes depend on it).
    device: explicit backend platform ("cpu"/"gpu"/"tpu") the decision is
    for; None keys off the process default backend (the historical
    behaviour).  This is the pure HEURISTIC; the measured, table-driven
    resolution lives in ``stream.costmodel`` and falls back here
    bit-compatibly when no calibration table exists.
    """
    if vmem_budget is None:
        vmem_budget = DEFAULT_VMEM_BUDGET
    if requested == "sparse" or (requested == "auto"
                                 and cfg.shortlist_c > 0):
        if cfg.shortlist_c <= 0:
            raise ValueError(
                "path 'sparse' requires cfg.shortlist_c > 0")
        return "sparse"
    if requested in ("scan", "vmem"):
        return requested
    if requested != "auto":
        raise ValueError(f"unknown path {requested!r}")
    working_set = cfg.kmax * cfg.dim * cfg.dim * 4
    backend = device if device else jax.default_backend()
    if (cfg.update_mode == "exact"
            and working_set <= vmem_budget
            and backend == "tpu"):
        return "vmem"
    return "scan"


NONFINITE_POLICIES = ("drop", "reject", "raise")


class NonFiniteChunkError(ValueError):
    """A chunk carried NaN/Inf rows under ``on_nonfinite="raise"``."""


def finite_guard(xc_host: np.ndarray, policy: str = "drop"
                 ) -> Tuple[np.ndarray, int]:
    """Quarantine non-finite rows BEFORE they can touch Λ.

    One NaN coordinate reaching the rank-one update poisons a component's
    (mu, Λ, logdet) forever — and, through consolidation, the global
    snapshot; the single-pass design has no replay to heal from.  So the
    guard runs on the host chunk ahead of every device dispatch:

      "drop"   keep only the finite rows (per-row quarantine).  Since
               chunking never changes the math (the PR-1 invariant), the
               resulting state is bit-identical to ingesting a stream
               that never contained the poisoned rows.
      "reject" quarantine the WHOLE chunk (a poisoned producer is not
               trusted for the rest of its batch).
      "raise"  raise NonFiniteChunkError (strict pipelines that must
               halt on corrupt input).

    Returns ``(kept_rows, n_quarantined)``.  The all-finite fast path
    returns the input array UNTOUCHED (same object) so the runtime can
    keep using the already-in-flight device copy — zero overhead beyond
    one vectorised isfinite sweep.
    """
    if policy not in NONFINITE_POLICIES:
        raise ValueError(
            f"on_nonfinite must be one of {NONFINITE_POLICIES}")
    finite = np.isfinite(xc_host).all(axis=1)
    if finite.all():
        return xc_host, 0
    if policy == "raise":
        bad = int((~finite).sum())
        raise NonFiniteChunkError(
            f"{bad}/{xc_host.shape[0]} non-finite rows in chunk "
            f"(on_nonfinite='raise')")
    if policy == "reject":
        return xc_host[:0], int(xc_host.shape[0])
    return xc_host[finite], int((~finite).sum())


class DoubleBufferedLoader:
    """Chunked host→device feed with one chunk of transfer lookahead.

    ``jax.device_put`` is asynchronous: issuing the put for chunk i+1 before
    the consumer blocks on chunk i overlaps the H2D copy with the device
    compute on the current chunk — the classic double buffer, with XLA's
    transfer engine as the second buffer.
    """

    def __init__(self, xs, chunk: int, dtype=jnp.float32):
        self._np = np.asarray(xs)
        if self._np.ndim != 2:
            raise ValueError(f"expected (N, D) stream, got {self._np.shape}")
        self.chunk = int(chunk)
        self.dtype = dtype

    def __len__(self) -> int:
        return -(-self._np.shape[0] // self.chunk) if self._np.size else 0

    def _put(self, a: int, b: int) -> Array:
        return jax.device_put(jnp.asarray(self._np[a:b], self.dtype))

    def __iter__(self) -> Iterator[Tuple[Array, np.ndarray]]:
        """Yields (device_chunk, host_chunk) pairs in stream order."""
        n = self._np.shape[0]
        bounds = [(i, min(i + self.chunk, n))
                  for i in range(0, n, self.chunk)]
        if not bounds:
            return
        nxt = self._put(*bounds[0])
        for j, (a, b) in enumerate(bounds):
            cur = nxt
            if j + 1 < len(bounds):
                nxt = self._put(*bounds[j + 1])   # overlap with consumer
            yield cur, self._np[a:b]


def fit_chunk_scan(cfg: FIGMNConfig, state: FIGMNState, xc: Array,
                   do_prune: bool) -> FIGMNState:
    """Reference path: lax.scan of learn_one — identical math to figmn.fit.

    ``figmn.fit`` donates the state, so the (K, D, D) Λ buffer is reused
    in place across chunks; callers must rebind (the runtime does).
    """
    return figmn.fit(cfg, state, xc, do_prune=do_prune)


def fit_chunk_sparse(cfg: FIGMNConfig, state: FIGMNState, xc: Array,
                     do_prune: bool) -> FIGMNState:
    """Shortlist path: top-C sparse scan — bit-identical to "scan" when
    cfg.shortlist_c ≥ active K, O(K·D + C·D²) per point otherwise.  Also
    donates the state like the scan body."""
    return shortlist.fit_sparse(cfg, state, xc, do_prune=do_prune)


def fit_chunk_vmem(cfg: FIGMNConfig, state: FIGMNState, xc: Array
                   ) -> Tuple[FIGMNState, Array]:
    """VMEM-resident path: whole chunk in one pallas_call.

    Creation events are no-ops inside the kernel (gate-failing points leave
    the state untouched); the caller collects them via ``chunk_stats`` for
    the lifecycle spawn buffer.  Returns (state', n_accepted) with the
    accept counter left ON DEVICE — pulling it here would block the host
    on every chunk; the runtime folds it into telemetry at lifecycle
    boundaries instead.
    """
    n = int(xc.shape[0])
    thresh = jnp.asarray(
        [float(chi2_quantile(cfg.dim, 1.0 - cfg.beta))], jnp.float32)
    mu, lam, logdet, sp, nacc = figmn_stream.figmn_stream_pallas(
        xc.astype(jnp.float32), state.mu.astype(jnp.float32),
        state.lam.astype(jnp.float32), state.logdet.astype(jnp.float32),
        state.sp.astype(jnp.float32), state.active.astype(jnp.int32),
        thresh, dim=cfg.dim, n_points=n,
        interpret=jax.default_backend() != "tpu")
    dt = cfg.dtype
    new = FIGMNState(
        mu=mu.astype(dt), lam=lam.astype(dt), logdet=logdet.astype(dt),
        sp=sp.astype(dt),
        # eq. 4: every active component ages once per point
        v=state.v + n * state.active.astype(dt),
        active=state.active, n_created=state.n_created)
    return new, nacc[0]


@jax.jit
def chunk_stats(cfg: FIGMNConfig, state: FIGMNState, xc: Array,
                thresh: Array) -> Tuple[Array, Array]:
    """(fails (B,) bool, mean mixture log-likelihood ()) vs frozen params.

    ONE batched pass over Λ (``figmn.log_joint_batch`` — the same
    implementation ``figmn.score_batch`` reduces) yields d² (B, K), which
    feeds BOTH the chi² gate (→ lifecycle spawn buffer / novelty rate) and
    the mixture log-density (→ drift CUSUM): enabling drift detection
    costs a single extra Λ read per chunk, not one per statistic.
    """
    d2, logjoint = figmn.log_joint_batch(cfg, state, xc)
    fails = ~jnp.any(state.active[None, :] & (d2 < thresh), axis=1)
    ll = jax.scipy.special.logsumexp(logjoint, axis=1)
    return fails, jnp.mean(ll)


learn_one_jit = jax.jit(figmn.learn_one, static_argnames=("do_prune",))

score_batch_jit = jax.jit(figmn.score_batch)
