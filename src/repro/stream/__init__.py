"""repro.stream — the production streaming runtime for the Fast IGMN.

The paper (Pinto & Engel 2015) defines a single-pass O(NKD²) learner; this
package supplies everything around it that an unbounded, non-stationary
production stream needs:

  ingest.py     micro-batch chunking + double-buffered H2D + path dispatch
  costmodel.py  device-calibrated dispatch cost model (measured
                select_path: CostTable + calibrate + decide/resolve)
  lifecycle.py  component-pool management under a fixed K budget
  drift.py      novelty-gate + log-likelihood-CUSUM drift detection
  telemetry.py  per-chunk metrics, feeding repro.ft.anomaly
  runtime.py    the StreamRuntime orchestrator (checkpoint-backed resume)

Design lineage: the lifecycle/drift split follows Pinto & Engel's follow-up
("Scalable and Incremental Learning of Gaussian Mixture Models", 2017) and
Gepperth & Pfülb ("Gradient-based training of GMMs for High-Dimensional
Streaming Data", 2019): the per-point update stays the paper's fast rank-one
algebra, while everything that changes the pool's SHAPE (spawn/prune/merge)
runs off the hot path at a fixed cadence so jitted shapes stay static.
"""
from repro.stream.costmodel import CostTable, DispatchDecision
from repro.stream.drift import DriftConfig, DriftDetector
from repro.stream.ingest import (DoubleBufferedLoader,
                                 NonFiniteChunkError, finite_guard,
                                 select_path)
from repro.stream.lifecycle import FailureBuffer, LifecycleConfig
from repro.stream.runtime import RuntimeConfig, StreamRuntime
from repro.stream.telemetry import ChunkMetrics, Telemetry

__all__ = [
    "ChunkMetrics", "CostTable", "DispatchDecision",
    "DoubleBufferedLoader", "DriftConfig", "DriftDetector",
    "FailureBuffer", "LifecycleConfig", "NonFiniteChunkError",
    "RuntimeConfig", "StreamRuntime", "Telemetry", "finite_guard",
    "select_path",
]
